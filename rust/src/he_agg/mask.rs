//! Encryption masks: which parameters get homomorphically protected.
//!
//! The paper's Selective Parameter Encryption ranks parameters by the
//! securely-aggregated sensitivity map and encrypts the top-`p` fraction;
//! random selection is the weaker baseline of Fig. 9; the "first and last
//! layers" heuristic is the Empirical Selection Recipe of §4.2.2.
//!
//! Real masks are run-structured (layer ranges, the first-and-last-layer
//! recipe, contiguous sensitivity blocks), so the mask core is a run-length
//! [`MaskLayout`] — sorted, non-overlapping, coalesced `[lo, hi)` intervals
//! over the flat parameter space — rather than the seed's one-`u32`-per-index
//! list. That makes mask memory and wire cost O(runs) instead of O(encrypted
//! params) (a layer-granularity BERT mask is a few hundred bytes, not ~44 MB)
//! and turns the encrypt/decrypt gather/scatter paths into contiguous segment
//! copies instead of per-index indirection.

use crate::crypto::prng::ChaChaRng;

/// One half-open interval `[lo, hi)` of the flat parameter space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Run {
    pub lo: usize,
    pub hi: usize,
}

impl Run {
    pub fn len(&self) -> usize {
        self.hi.saturating_sub(self.lo)
    }

    pub fn is_empty(&self) -> bool {
        self.hi <= self.lo
    }
}

/// A set of coordinates of a flat `total`-parameter vector, stored as sorted,
/// non-overlapping, non-adjacent (coalesced) `[lo, hi)` runs.
///
/// Invariants (enforced by every constructor):
/// * `runs[i].lo < runs[i].hi <= total`
/// * `runs[i].hi < runs[i+1].lo` (strictly — adjacent runs are merged)
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MaskLayout {
    total: usize,
    runs: Vec<Run>,
    /// Cached Σ run lengths.
    count: usize,
}

impl MaskLayout {
    /// No coordinates.
    pub fn empty(total: usize) -> Self {
        MaskLayout { total, runs: Vec::new(), count: 0 }
    }

    /// Every coordinate.
    pub fn full(total: usize) -> Self {
        if total == 0 {
            return Self::empty(0);
        }
        MaskLayout {
            total,
            runs: vec![Run { lo: 0, hi: total }],
            count: total,
        }
    }

    /// Build from arbitrary runs: clamps to `[0, total)`, drops empties,
    /// sorts, and coalesces overlapping/adjacent intervals.
    pub fn from_runs(total: usize, mut runs: Vec<Run>) -> Self {
        for r in runs.iter_mut() {
            r.lo = r.lo.min(total);
            r.hi = r.hi.min(total);
        }
        runs.retain(|r| !r.is_empty());
        runs.sort_by_key(|r| r.lo);
        let mut out: Vec<Run> = Vec::with_capacity(runs.len());
        for r in runs {
            match out.last_mut() {
                Some(last) if r.lo <= last.hi => last.hi = last.hi.max(r.hi),
                _ => out.push(r),
            }
        }
        let count = out.iter().map(Run::len).sum();
        MaskLayout { total, runs: out, count }
    }

    /// Build from ascending (possibly duplicated) indices, coalescing
    /// consecutive ones into runs in a single scan. Indices `>= total` are
    /// ignored; unsorted input falls back to an O(n log n) sort-and-coalesce
    /// so no index is ever silently dropped.
    pub fn from_sorted_indices(total: usize, indices: &[u32]) -> Self {
        let mut runs: Vec<Run> = Vec::new();
        let mut prev: Option<usize> = None;
        for &i in indices {
            let i = i as usize;
            if prev.is_some_and(|p| i < p) {
                // out-of-order input: the single-scan coalescer would drop
                // indices that land before the current run — re-sort instead
                let all = indices
                    .iter()
                    .map(|&j| Run { lo: j as usize, hi: j as usize + 1 })
                    .collect();
                return Self::from_runs(total, all);
            }
            prev = Some(i);
            if i >= total {
                continue;
            }
            match runs.last_mut() {
                Some(last) if i < last.hi => {} // duplicate
                Some(last) if i == last.hi => last.hi = i + 1,
                _ => runs.push(Run { lo: i, hi: i + 1 }),
            }
        }
        let count = runs.iter().map(Run::len).sum();
        MaskLayout { total, runs, count }
    }

    /// Length of the underlying flat parameter space.
    pub fn total(&self) -> usize {
        self.total
    }

    /// The coalesced runs, sorted by `lo`.
    pub fn runs(&self) -> &[Run] {
        &self.runs
    }

    /// Number of runs (the O(·) factor of mask memory and wire cost).
    pub fn n_runs(&self) -> usize {
        self.runs.len()
    }

    /// Number of covered coordinates.
    pub fn count(&self) -> usize {
        self.count
    }

    /// Whether coordinate `i` is covered (binary search over runs).
    pub fn contains(&self, i: usize) -> bool {
        self.runs
            .binary_search_by(|r| {
                if i < r.lo {
                    std::cmp::Ordering::Greater
                } else if i >= r.hi {
                    std::cmp::Ordering::Less
                } else {
                    std::cmp::Ordering::Equal
                }
            })
            .is_ok()
    }

    /// The uncovered coordinates as a layout over the same space.
    pub fn complement(&self) -> MaskLayout {
        let mut runs = Vec::with_capacity(self.runs.len() + 1);
        let mut prev = 0usize;
        for r in &self.runs {
            if r.lo > prev {
                runs.push(Run { lo: prev, hi: r.lo });
            }
            prev = r.hi;
        }
        if prev < self.total {
            runs.push(Run { lo: prev, hi: self.total });
        }
        MaskLayout {
            total: self.total,
            runs,
            count: self.total - self.count,
        }
    }

    /// Set union over the same parameter space.
    pub fn union(&self, other: &MaskLayout) -> MaskLayout {
        assert_eq!(self.total, other.total, "layout space mismatch");
        let mut all: Vec<Run> = Vec::with_capacity(self.runs.len() + other.runs.len());
        all.extend_from_slice(&self.runs);
        all.extend_from_slice(&other.runs);
        MaskLayout::from_runs(self.total, all)
    }

    /// Iterate covered coordinates in ascending order.
    pub fn iter_indices(&self) -> impl Iterator<Item = usize> + '_ {
        self.runs.iter().flat_map(|r| r.lo..r.hi)
    }

    /// Dense boolean view — for attack simulation and test oracles only;
    /// never used on the encrypt/decrypt hot paths.
    pub fn to_dense(&self) -> Vec<bool> {
        let mut v = vec![false; self.total];
        for r in &self.runs {
            v[r.lo..r.hi].fill(true);
        }
        v
    }

    /// Run-delta wire format (the mask-distribution message of Algorithm 1
    /// round 1): `u64 total | u32 n_runs | (varint gap, varint len)*` where
    /// `gap` is the distance from the previous run's end (`lo` for the first
    /// run) and `len` the run length. O(runs) bytes — a layer-granularity
    /// mask over 100M+ parameters serializes in well under a kilobyte.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(12 + 4 * self.runs.len());
        out.extend_from_slice(&(self.total as u64).to_le_bytes());
        out.extend_from_slice(&(self.runs.len() as u32).to_le_bytes());
        let mut prev_hi = 0usize;
        for r in &self.runs {
            write_varint(&mut out, (r.lo - prev_hi) as u64);
            write_varint(&mut out, r.len() as u64);
            prev_hi = r.hi;
        }
        out
    }

    /// Parse and validate the run-delta wire format. Rejects truncation,
    /// trailing bytes, zero-length runs, un-coalesced (gap-0) runs, and runs
    /// beyond `total`.
    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<Self> {
        anyhow::ensure!(bytes.len() >= 12, "truncated mask header");
        let total = u64::from_le_bytes(bytes[0..8].try_into().unwrap());
        anyhow::ensure!(total <= usize::MAX as u64, "mask total overflows usize");
        let total = total as usize;
        let n_runs = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        // each run is at least two varint bytes — bound the allocation before
        // trusting the declared count
        anyhow::ensure!(
            bytes.len() - 12 >= 2 * n_runs,
            "declared run count exceeds payload"
        );
        let mut pos = 12usize;
        let mut runs = Vec::with_capacity(n_runs);
        let mut prev_hi = 0usize;
        for i in 0..n_runs {
            let gap = read_varint(bytes, &mut pos)?;
            let len = read_varint(bytes, &mut pos)?;
            anyhow::ensure!(len >= 1, "zero-length mask run");
            anyhow::ensure!(i == 0 || gap >= 1, "mask runs must be coalesced");
            let lo = (prev_hi as u64)
                .checked_add(gap)
                .ok_or_else(|| anyhow::anyhow!("mask run offset overflow"))?;
            let hi = lo
                .checked_add(len)
                .ok_or_else(|| anyhow::anyhow!("mask run length overflow"))?;
            anyhow::ensure!(hi <= total as u64, "mask run out of range");
            runs.push(Run { lo: lo as usize, hi: hi as usize });
            prev_hi = hi as usize;
        }
        anyhow::ensure!(pos == bytes.len(), "trailing bytes after mask runs");
        let count = runs.iter().map(Run::len).sum();
        Ok(MaskLayout { total, runs, count })
    }
}

fn write_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            break;
        }
        out.push(b | 0x80);
    }
}

fn read_varint(bytes: &[u8], pos: &mut usize) -> anyhow::Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        anyhow::ensure!(*pos < bytes.len(), "truncated varint");
        anyhow::ensure!(shift < 64, "varint overflow");
        let b = bytes[*pos];
        *pos += 1;
        // at shift 63 only the lowest payload bit fits in a u64; higher bits
        // would silently shift out and alias to a different value
        anyhow::ensure!(shift < 63 || (b & 0x7f) <= 1, "varint overflow");
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

/// A binary encryption mask over a flat parameter vector: the encrypted
/// (protected) coordinates as a run-length [`MaskLayout`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EncryptionMask {
    /// Runs of encrypted (protected) parameters.
    pub encrypted: MaskLayout,
}

impl EncryptionMask {
    /// Encrypt everything (the vanilla-HE baseline).
    pub fn full(total: usize) -> Self {
        EncryptionMask { encrypted: MaskLayout::full(total) }
    }

    /// Encrypt nothing (plaintext FedAvg).
    pub fn empty(total: usize) -> Self {
        EncryptionMask { encrypted: MaskLayout::empty(total) }
    }

    /// Build from explicit runs (clamped/coalesced).
    pub fn from_runs(total: usize, runs: Vec<Run>) -> Self {
        EncryptionMask { encrypted: MaskLayout::from_runs(total, runs) }
    }

    /// Build from sorted encrypted indices.
    pub fn from_indices(total: usize, indices: &[u32]) -> Self {
        EncryptionMask {
            encrypted: MaskLayout::from_sorted_indices(total, indices),
        }
    }

    /// Top-`p` fraction by sensitivity (the paper's selection strategy).
    /// Degenerate inputs (empty slice, NaN `p`, `p <= 0`) yield the empty
    /// mask rather than panicking.
    pub fn top_p(sensitivity: &[f32], p: f64) -> Self {
        let total = sensitivity.len();
        let k = fraction_count(total, p);
        if k == 0 {
            return Self::empty(total);
        }
        if k == total {
            return Self::full(total);
        }
        assert!(total <= u32::MAX as usize, "per-index selection is u32-indexed");
        let mut idx: Vec<u32> = (0..total as u32).collect();
        // Partial selection: k largest by sensitivity (k < total here, so the
        // pivot index is in range even for a 1-element slice).
        idx.select_nth_unstable_by(k, |&a, &b| {
            sensitivity[b as usize]
                .partial_cmp(&sensitivity[a as usize])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut encrypted = idx[..k].to_vec();
        encrypted.sort_unstable();
        Self::from_indices(total, &encrypted)
    }

    /// Uniform-random `p` fraction (Fig. 9's baseline). Same degenerate-input
    /// guards as [`EncryptionMask::top_p`].
    pub fn random(total: usize, p: f64, rng: &mut ChaChaRng) -> Self {
        let k = fraction_count(total, p);
        if k == 0 {
            return Self::empty(total);
        }
        if k == total {
            return Self::full(total);
        }
        assert!(total <= u32::MAX as usize, "per-index selection is u32-indexed");
        let mut idx: Vec<u32> = (0..total as u32).collect();
        rng.shuffle(&mut idx);
        let mut encrypted = idx[..k].to_vec();
        encrypted.sort_unstable();
        Self::from_indices(total, &encrypted)
    }

    /// The Empirical Selection Recipe: top-`p` sensitive parameters plus the
    /// first and last layer ranges — a run union, no dense materialization.
    pub fn recipe(
        sensitivity: &[f32],
        p: f64,
        first_layer: std::ops::Range<usize>,
        last_layer: std::ops::Range<usize>,
    ) -> Self {
        let total = sensitivity.len();
        let base = Self::top_p(sensitivity, p);
        let layers = MaskLayout::from_runs(
            total,
            vec![
                Run { lo: first_layer.start, hi: first_layer.end },
                Run { lo: last_layer.start, hi: last_layer.end },
            ],
        );
        EncryptionMask { encrypted: base.encrypted.union(&layers) }
    }

    /// Layer-granularity selection over pre-aggregated per-layer scores:
    /// encrypt whole layers, highest score first, until at least `p` of the
    /// parameter space is covered. The practical deployment mode — the mask
    /// is O(layers) runs and the mask-agreement message carries one score
    /// per layer instead of one per parameter.
    pub fn from_layer_scores(
        total: usize,
        scores: &[f32],
        layers: &[std::ops::Range<usize>],
        p: f64,
    ) -> Self {
        assert_eq!(scores.len(), layers.len(), "one score per layer");
        let target = fraction_count(total, p);
        if target == 0 {
            return Self::empty(total);
        }
        let mut order: Vec<usize> = (0..layers.len())
            .filter(|&i| layers[i].start < layers[i].end)
            .collect();
        order.sort_by(|&a, &b| {
            scores[b]
                .partial_cmp(&scores[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        // accumulate as a coalesced union so overlapping spans never
        // double-count coverage toward the target
        let mut acc = MaskLayout::empty(total);
        for i in order {
            if acc.count() >= target {
                break;
            }
            let r = &layers[i];
            let span = MaskLayout::from_runs(total, vec![Run { lo: r.start, hi: r.end }]);
            acc = acc.union(&span);
        }
        EncryptionMask { encrypted: acc }
    }

    /// Layer-granularity selection from a full per-parameter sensitivity map:
    /// scores each layer by its mean sensitivity, then defers to
    /// [`EncryptionMask::from_layer_scores`].
    pub fn layer_granular(
        sensitivity: &[f32],
        p: f64,
        layers: &[std::ops::Range<usize>],
    ) -> Self {
        let total = sensitivity.len();
        let scores = layer_mean_scores(sensitivity, layers);
        Self::from_layer_scores(total, &scores, layers, p)
    }

    /// Total parameter count of the flat space.
    pub fn total(&self) -> usize {
        self.encrypted.total()
    }

    /// The encrypted runs, sorted by `lo`.
    pub fn runs(&self) -> &[Run] {
        self.encrypted.runs()
    }

    /// Number of encrypted parameters.
    pub fn encrypted_count(&self) -> usize {
        self.encrypted.count()
    }

    /// Actual encrypted ratio.
    pub fn ratio(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            self.encrypted_count() as f64 / self.total() as f64
        }
    }

    /// The plaintext (unencrypted) coordinates as runs — the layout the
    /// compacted plaintext remainder is scattered from.
    pub fn plaintext_layout(&self) -> MaskLayout {
        self.encrypted.complement()
    }

    /// Dense boolean view (for attack simulation / test oracles).
    pub fn to_dense(&self) -> Vec<bool> {
        self.encrypted.to_dense()
    }

    /// Serialize in the run-delta wire format (see [`MaskLayout::to_bytes`]).
    pub fn to_bytes(&self) -> Vec<u8> {
        self.encrypted.to_bytes()
    }

    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<Self> {
        Ok(EncryptionMask { encrypted: MaskLayout::from_bytes(bytes)? })
    }
}

/// `round(total · p)` clamped to `[0, total]`, treating NaN `p` as 0.
fn fraction_count(total: usize, p: f64) -> usize {
    if total == 0 || p.is_nan() || p <= 0.0 {
        return 0;
    }
    (((total as f64) * p.clamp(0.0, 1.0)).round() as usize).min(total)
}

/// Mean sensitivity per layer span (empty spans score 0).
pub fn layer_mean_scores(sensitivity: &[f32], layers: &[std::ops::Range<usize>]) -> Vec<f32> {
    layers
        .iter()
        .map(|r| {
            let hi = r.end.min(sensitivity.len());
            let lo = r.start.min(hi);
            if lo >= hi {
                return 0.0;
            }
            let sum: f64 = sensitivity[lo..hi].iter().map(|&s| s as f64).sum();
            (sum / (hi - lo) as f64) as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn indices(m: &EncryptionMask) -> Vec<usize> {
        m.encrypted.iter_indices().collect()
    }

    #[test]
    fn top_p_selects_most_sensitive() {
        let s: Vec<f32> = vec![0.1, 5.0, 0.2, 4.0, 0.05, 3.0, 0.3, 2.0, 0.01, 1.0];
        let m = EncryptionMask::top_p(&s, 0.3);
        assert_eq!(indices(&m), vec![1, 3, 5]); // sensitivities 5,4,3
        assert_eq!(m.encrypted_count(), 3);
        assert_eq!(m.encrypted.n_runs(), 3); // non-adjacent singletons
        assert!((m.ratio() - 0.3).abs() < 1e-9);
    }

    #[test]
    fn top_p_extremes() {
        let s = vec![1.0f32; 100];
        assert_eq!(EncryptionMask::top_p(&s, 0.0).encrypted_count(), 0);
        assert_eq!(EncryptionMask::top_p(&s, 1.0).encrypted_count(), 100);
        // full coverage coalesces to a single run
        assert_eq!(EncryptionMask::top_p(&s, 1.0).encrypted.n_runs(), 1);
        assert_eq!(EncryptionMask::full(100).encrypted_count(), 100);
        assert_eq!(EncryptionMask::empty(100).encrypted_count(), 0);
    }

    #[test]
    fn degenerate_inputs_do_not_panic() {
        // empty sensitivity slice (the seed's select_nth panic)
        let m = EncryptionMask::top_p(&[], 0.5);
        assert_eq!(m.total(), 0);
        assert_eq!(m.encrypted_count(), 0);
        // NaN and out-of-range p
        let s = vec![1.0f32; 10];
        assert_eq!(EncryptionMask::top_p(&s, f64::NAN).encrypted_count(), 0);
        assert_eq!(EncryptionMask::top_p(&s, -3.0).encrypted_count(), 0);
        assert_eq!(EncryptionMask::top_p(&s, 7.0).encrypted_count(), 10);
        // single-element slice at both extremes
        assert_eq!(EncryptionMask::top_p(&[1.0], 1.0).encrypted_count(), 1);
        assert_eq!(EncryptionMask::top_p(&[1.0], 0.0).encrypted_count(), 0);
        // total == 0 everywhere
        let mut rng = ChaChaRng::from_seed(1, 0);
        assert_eq!(EncryptionMask::random(0, 0.5, &mut rng).encrypted_count(), 0);
        assert_eq!(EncryptionMask::full(0).encrypted.n_runs(), 0);
        assert_eq!(
            EncryptionMask::random(10, f64::NAN, &mut rng).encrypted_count(),
            0
        );
        assert_eq!(EncryptionMask::recipe(&[], 0.5, 0..0, 0..0).total(), 0);
        assert_eq!(
            EncryptionMask::layer_granular(&[], 0.5, &[]).encrypted_count(),
            0
        );
    }

    #[test]
    fn random_mask_has_right_size_and_spread() {
        let mut rng = ChaChaRng::from_seed(1, 0);
        let m = EncryptionMask::random(10_000, 0.25, &mut rng);
        assert_eq!(m.encrypted_count(), 2500);
        // sorted, coalesced runs
        for w in m.runs().windows(2) {
            assert!(w[0].hi < w[1].lo);
        }
        // roughly uniform: mean index near total/2
        let mean: f64 = m.encrypted.iter_indices().map(|i| i as f64).sum::<f64>()
            / m.encrypted_count() as f64;
        assert!((mean - 5000.0).abs() < 300.0);
    }

    #[test]
    fn recipe_includes_boundary_layers() {
        let s = vec![0.0f32; 100];
        let m = EncryptionMask::recipe(&s, 0.0, 0..10, 90..100);
        assert_eq!(m.encrypted_count(), 20);
        assert_eq!(m.encrypted.n_runs(), 2);
        assert!(m.encrypted.contains(0) && m.encrypted.contains(99));
        assert!(!m.encrypted.contains(50));
    }

    #[test]
    fn unsorted_indices_are_not_dropped() {
        // the single-scan coalescer falls back to sort-and-coalesce
        let m = EncryptionMask::from_indices(100, &[5, 3, 4, 3, 90]);
        assert_eq!(indices(&m), vec![3, 4, 5, 90]);
        assert_eq!(m.encrypted.n_runs(), 2);
    }

    #[test]
    fn from_runs_normalizes() {
        // overlapping + adjacent + out-of-range + empty runs all coalesce
        let m = EncryptionMask::from_runs(
            100,
            vec![
                Run { lo: 10, hi: 20 },
                Run { lo: 15, hi: 25 },
                Run { lo: 25, hi: 30 },
                Run { lo: 50, hi: 50 },
                Run { lo: 90, hi: 200 },
            ],
        );
        assert_eq!(m.runs(), &[Run { lo: 10, hi: 30 }, Run { lo: 90, hi: 100 }]);
        assert_eq!(m.encrypted_count(), 30);
    }

    #[test]
    fn complement_partitions_the_space() {
        let s: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let m = EncryptionMask::top_p(&s, 0.4);
        let plain = m.plaintext_layout();
        assert_eq!(m.encrypted_count() + plain.count(), 10);
        let mut all: Vec<usize> = m
            .encrypted
            .iter_indices()
            .chain(plain.iter_indices())
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
        // complement of the complement is the original
        assert_eq!(plain.complement(), m.encrypted);
    }

    #[test]
    fn union_merges_overlaps() {
        let a = MaskLayout::from_runs(50, vec![Run { lo: 0, hi: 10 }, Run { lo: 30, hi: 35 }]);
        let b = MaskLayout::from_runs(50, vec![Run { lo: 5, hi: 12 }, Run { lo: 35, hi: 40 }]);
        let u = a.union(&b);
        assert_eq!(u.runs(), &[Run { lo: 0, hi: 12 }, Run { lo: 30, hi: 40 }]);
        assert_eq!(u.count(), 22);
    }

    #[test]
    fn layer_granular_selects_whole_layers() {
        // 4 layers of 25 params; layer 2 then layer 0 are most sensitive
        let mut s = vec![0.1f32; 100];
        for v in s[50..75].iter_mut() {
            *v = 9.0;
        }
        for v in s[0..25].iter_mut() {
            *v = 5.0;
        }
        let layers = [0..25, 25..50, 50..75, 75..100];
        let m = EncryptionMask::layer_granular(&s, 0.3, &layers);
        // target 30 params → layer 2 (25) then layer 0 (25) → 50 covered
        assert_eq!(m.encrypted_count(), 50);
        assert_eq!(m.runs(), &[Run { lo: 0, hi: 25 }, Run { lo: 50, hi: 75 }]);
        // p=0 still empty; p=1 covers everything layer by layer
        assert_eq!(EncryptionMask::layer_granular(&s, 0.0, &layers).encrypted_count(), 0);
        assert_eq!(
            EncryptionMask::layer_granular(&s, 1.0, &layers).encrypted_count(),
            100
        );
    }

    #[test]
    fn overlapping_layer_spans_do_not_double_count() {
        // spans 0 and 1 are the same region; coverage must not count twice,
        // so span 2 is still needed to reach the 75% target
        let m = EncryptionMask::from_layer_scores(
            100,
            &[3.0, 2.0, 1.0],
            &[0..50, 0..50, 50..100],
            0.75,
        );
        assert_eq!(m.encrypted_count(), 100);
    }

    #[test]
    fn bytes_roundtrip_and_validation() {
        let s: Vec<f32> = (0..1000).map(|i| ((i * 7919) % 997) as f32).collect();
        let m = EncryptionMask::top_p(&s, 0.1);
        let b = m.to_bytes();
        assert_eq!(EncryptionMask::from_bytes(&b).unwrap(), m);
        // wire cost is O(runs), with a 12-byte header
        assert!(b.len() <= 12 + 20 * m.encrypted.n_runs());
        // truncation
        assert!(EncryptionMask::from_bytes(&b[..b.len() - 1]).is_err());
        assert!(EncryptionMask::from_bytes(&b[..4]).is_err());
        // trailing garbage
        let mut long = b.clone();
        long.push(0);
        assert!(EncryptionMask::from_bytes(&long).is_err());
    }

    #[test]
    fn malformed_runs_rejected() {
        // hand-build: total=100, 1 run of length 0
        let mut bad = Vec::new();
        bad.extend_from_slice(&100u64.to_le_bytes());
        bad.extend_from_slice(&1u32.to_le_bytes());
        bad.push(5); // gap 5
        bad.push(0); // len 0
        assert!(MaskLayout::from_bytes(&bad).is_err());
        // run beyond total: gap 90, len 20
        let mut oob = Vec::new();
        oob.extend_from_slice(&100u64.to_le_bytes());
        oob.extend_from_slice(&1u32.to_le_bytes());
        oob.push(90);
        oob.push(20);
        assert!(MaskLayout::from_bytes(&oob).is_err());
        // two adjacent runs (gap 0 on the second): must be coalesced
        let mut adj = Vec::new();
        adj.extend_from_slice(&100u64.to_le_bytes());
        adj.extend_from_slice(&2u32.to_le_bytes());
        adj.push(0); // run 0: [0, 5)
        adj.push(5);
        adj.push(0); // run 1: gap 0 → [5, 10) — not coalesced
        adj.push(5);
        assert!(MaskLayout::from_bytes(&adj).is_err());
        // a valid two-run encoding parses
        let ok = MaskLayout::from_runs(100, vec![Run { lo: 0, hi: 5 }, Run { lo: 6, hi: 10 }]);
        assert_eq!(MaskLayout::from_bytes(&ok.to_bytes()).unwrap(), ok);
    }

    #[test]
    fn full_mask_wire_is_constant_size() {
        // the vanilla-HE baseline over BERT-scale space: one run, 14 bytes
        let m = EncryptionMask::full(109_482_240);
        let b = m.to_bytes();
        assert!(b.len() < 32, "full mask wire {} bytes", b.len());
        assert_eq!(EncryptionMask::from_bytes(&b).unwrap(), m);
    }
}
