//! Selective Parameter Encryption (the paper's §2.4 contribution) and the
//! two aggregation backends.
//!
//! * [`mask`] — sensitivity-ranked encryption masks (top-p, random, layer
//!   heuristics) over a run-length interval layout ([`mask::MaskLayout`]):
//!   O(runs) memory and wire bytes, segment-copy gather/scatter.
//! * [`packing`] — run-aware ciphertext packing plans: how mask runs are
//!   cut into CKKS chunks (tight compacted layout vs the padded grid
//!   baseline the regression gate measures against).
//! * [`selective`] — split a flat parameter vector into an encrypted part
//!   (CKKS ciphertexts) and a compacted plaintext part, and merge back.
//! * [`native`] — pure-Rust aggregation (oracle + arbitrary-shape fallback).
//! * [`xla`] — aggregation through the AOT Pallas kernel via PJRT (the
//!   three-layer hot path).
//!
//! Both backends aggregate a whole round at once; the sharded streaming
//! alternative that overlaps intake with aggregation lives in
//! [`crate::agg_engine`] and produces bitwise-identical ciphertext limbs.

pub mod mask;
pub mod native;
pub mod packing;
pub mod selective;
pub mod xla;

pub use mask::{EncryptionMask, MaskLayout, Run};
pub use packing::{PackingMode, PackingPlan};
pub use selective::{CtArena, EncryptedUpdate, SelectiveCodec};
