//! XLA aggregation backend: drives the AOT Pallas kernels via PJRT.
//!
//! This is the three-layer hot path: the L1 `he_agg` kernel (modular
//! weighted sum over RNS limbs) and `plain_agg` kernel (f32 weighted sum)
//! were lowered once at build time for fixed shapes
//! `(N = agg_clients, C = agg_chunk, L, n)`; this module adapts arbitrary
//! client counts and model lengths onto those shapes:
//!
//! * clients are processed in groups of `agg_clients`, padding the last
//!   group with zero-weight entries (zero weight ⇒ zero contribution, exact
//!   in modular arithmetic);
//! * ciphertexts stream through the batched artifact `agg_chunk` at a time,
//!   the remainder through the single-ciphertext artifact;
//! * group partial sums are combined with native ciphertext additions
//!   (cheap; keeps every group at the same Δ·Δ_w scale).

use super::selective::EncryptedUpdate;
use crate::ckks::{Ciphertext, CkksParams, RnsPoly};
use crate::runtime::executor::{Arg, Runtime};
use std::sync::Arc;

/// Aggregator bound to a runtime and crypto parameters.
pub struct XlaAggregator<'a> {
    pub rt: &'a Runtime,
    pub params: Arc<CkksParams>,
}

impl<'a> XlaAggregator<'a> {
    pub fn new(rt: &'a Runtime, params: Arc<CkksParams>) -> anyhow::Result<Self> {
        rt.manifest.validate_crypto(&params)?;
        Ok(XlaAggregator { rt, params })
    }

    fn n_art(&self) -> usize {
        self.rt.manifest.agg_clients
    }
    fn chunk_art(&self) -> usize {
        self.rt.manifest.agg_chunk
    }
    fn plain_block(&self) -> usize {
        self.rt.manifest.plain_block
    }

    /// Flatten one ciphertext into u32 words (poly-major, limb-major).
    fn ct_words(&self, ct: &Ciphertext, out: &mut Vec<u32>) {
        for poly in [&ct.c0, &ct.c1] {
            out.extend(poly.flat().iter().map(|&c| c as u32));
        }
    }

    /// Zero words for a padding ciphertext.
    fn zero_words(&self, out: &mut Vec<u32>) {
        out.extend(std::iter::repeat(0u32).take(2 * self.params.num_limbs() * self.params.n));
    }

    /// Rebuild a ciphertext from kernel output words.
    fn ct_from_words(&self, words: &[u32], n_values: usize, scale: f64) -> Ciphertext {
        let n = self.params.n;
        let l = self.params.num_limbs();
        assert_eq!(words.len(), 2 * l * n);
        let mut polys = Vec::with_capacity(2);
        for p in 0..2 {
            let off = p * l * n;
            let data: Vec<u64> = words[off..off + l * n].iter().map(|&w| w as u64).collect();
            polys.push(RnsPoly::from_flat(n, l, data, false));
        }
        let c1 = polys.pop().unwrap();
        let c0 = polys.pop().unwrap();
        Ciphertext {
            c0,
            c1,
            n_values,
            scale,
            a_seed: None,
        }
    }

    /// Encoded per-limb weights for one client group, padded to `n_art`.
    fn group_weights(&self, alphas: &[f64]) -> Vec<u32> {
        let l = self.params.num_limbs();
        let mut w = Vec::with_capacity(self.n_art() * l);
        for i in 0..self.n_art() {
            if i < alphas.len() {
                for r in self.params.encode_weight(alphas[i]) {
                    w.push(r as u32);
                }
            } else {
                w.extend(std::iter::repeat(0u32).take(l));
            }
        }
        w
    }

    /// Aggregate the ciphertext lists of one client group (all of the same
    /// length) through the artifacts.
    fn aggregate_ct_group(
        &self,
        group: &[&EncryptedUpdate],
        alphas: &[f64],
    ) -> anyhow::Result<Vec<Ciphertext>> {
        let n_art = self.n_art();
        let l = self.params.num_limbs();
        let n = self.params.n;
        let ct_words = 2 * l * n;
        let n_cts = group[0].cts.len();
        let weights = self.group_weights(alphas);
        let out_scale = group[0].cts.first().map(|c| c.scale).unwrap_or(0.0)
            * self.params.delta_w();

        let mut out = Vec::with_capacity(n_cts);
        let chunk = self.chunk_art();
        let mut c0 = 0usize;
        while c0 < n_cts {
            let c_here = (n_cts - c0).min(chunk);
            if c_here == chunk {
                // batched artifact: x u32[N, C, 2, L, n]
                let mut x = Vec::with_capacity(n_art * chunk * ct_words);
                for i in 0..n_art {
                    for c in 0..chunk {
                        if i < group.len() {
                            self.ct_words(&group[i].cts[c0 + c], &mut x);
                        } else {
                            self.zero_words(&mut x);
                        }
                    }
                }
                let res = self.rt.execute(
                    "he_agg_batched",
                    &[
                        Arg::U32(
                            &x,
                            vec![n_art as i64, chunk as i64, 2, l as i64, n as i64],
                        ),
                        Arg::U32(&weights, vec![n_art as i64, l as i64]),
                    ],
                )?;
                let words = res[0].to_vec::<u32>()?;
                for c in 0..chunk {
                    let n_values = group
                        .iter()
                        .map(|u| u.cts[c0 + c].n_values)
                        .max()
                        .unwrap();
                    out.push(self.ct_from_words(
                        &words[c * ct_words..(c + 1) * ct_words],
                        n_values,
                        out_scale,
                    ));
                }
                c0 += chunk;
            } else {
                // single-ciphertext artifact for the tail
                let mut x = Vec::with_capacity(n_art * ct_words);
                for i in 0..n_art {
                    if i < group.len() {
                        self.ct_words(&group[i].cts[c0], &mut x);
                    } else {
                        self.zero_words(&mut x);
                    }
                }
                let res = self.rt.execute(
                    "he_agg",
                    &[
                        Arg::U32(&x, vec![n_art as i64, 2, l as i64, n as i64]),
                        Arg::U32(&weights, vec![n_art as i64, l as i64]),
                    ],
                )?;
                let words = res[0].to_vec::<u32>()?;
                let n_values = group.iter().map(|u| u.cts[c0].n_values).max().unwrap();
                out.push(self.ct_from_words(&words, n_values, out_scale));
                c0 += 1;
            }
        }
        Ok(out)
    }

    /// Plaintext weighted sum of one client group through `plain_agg`.
    fn aggregate_plain_group(
        &self,
        group: &[&EncryptedUpdate],
        alphas: &[f64],
    ) -> anyhow::Result<Vec<f32>> {
        let n_art = self.n_art();
        let block = self.plain_block();
        let len = group[0].plain.len();
        let mut w = vec![0.0f32; n_art];
        for (i, &a) in alphas.iter().enumerate() {
            w[i] = a as f32;
        }
        let mut out = Vec::with_capacity(len);
        let mut off = 0usize;
        while off < len {
            let here = (len - off).min(block);
            let mut x = vec![0.0f32; n_art * block];
            for (i, u) in group.iter().enumerate() {
                x[i * block..i * block + here].copy_from_slice(&u.plain[off..off + here]);
            }
            let res = self.rt.execute(
                "plain_agg",
                &[
                    Arg::F32(&x, vec![n_art as i64, block as i64]),
                    Arg::F32(&w, vec![n_art as i64]),
                ],
            )?;
            let v = res[0].to_vec::<f32>()?;
            out.extend_from_slice(&v[..here]);
            off += here;
        }
        Ok(out)
    }

    /// Full aggregation of Algorithm 1 through the XLA artifacts.
    pub fn aggregate(
        &self,
        updates: &[EncryptedUpdate],
        alphas: &[f64],
    ) -> anyhow::Result<EncryptedUpdate> {
        anyhow::ensure!(updates.len() == alphas.len() && !updates.is_empty());
        let n_art = self.n_art();
        let mut acc: Option<EncryptedUpdate> = None;
        for (g, chunk) in updates.chunks(n_art).enumerate() {
            let group: Vec<&EncryptedUpdate> = chunk.iter().collect();
            let a = &alphas[g * n_art..g * n_art + chunk.len()];
            let cts = self.aggregate_ct_group(&group, a)?;
            let plain = self.aggregate_plain_group(&group, a)?;
            let part = EncryptedUpdate {
                cts,
                plain,
                total: updates[0].total,
            };
            match &mut acc {
                None => acc = Some(part),
                Some(existing) => {
                    // combine group partial sums (same scale): native adds
                    for (e, p) in existing.cts.iter_mut().zip(part.cts.iter()) {
                        crate::ckks::ops::add_assign(e, p, &self.params);
                    }
                    for (e, p) in existing.plain.iter_mut().zip(part.plain.iter()) {
                        *e += p;
                    }
                }
            }
        }
        Ok(acc.unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::CkksContext;
    use crate::crypto::prng::ChaChaRng;
    use crate::he_agg::mask::EncryptionMask;
    use crate::he_agg::native;
    use crate::he_agg::selective::SelectiveCodec;
    use std::path::PathBuf;

    fn runtime() -> Option<Runtime> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Runtime::new(dir).unwrap())
    }

    fn setup(rt: &Runtime) -> (SelectiveCodec, ChaChaRng) {
        let c = &rt.manifest.crypto;
        let ctx = CkksContext::new(c.n, c.num_limbs, c.scaling_bits).unwrap();
        (SelectiveCodec::new(ctx), ChaChaRng::from_seed(77, 0))
    }

    /// The backbone cross-check: XLA kernel output must be bit-identical to
    /// the native Rust aggregator on the ciphertext limbs.
    #[test]
    fn xla_matches_native_bit_exact() {
        let Some(rt) = runtime() else { return };
        let (codec, mut rng) = setup(&rt);
        let (pk, _sk) = codec.ctx.keygen(&mut rng);
        let n_clients = 3;
        let alphas = [0.5, 0.3, 0.2];
        let total = 10_000; // 3 ciphertexts at batch 4096
        let sens: Vec<f32> = (0..total).map(|i| ((i * 7) % 1009) as f32).collect();
        let mask = EncryptionMask::top_p(&sens, 0.6);
        let models: Vec<Vec<f32>> = (0..n_clients)
            .map(|c| (0..total).map(|i| ((i + c * 97) as f32 * 0.001).sin()).collect())
            .collect();
        let updates: Vec<_> = models
            .iter()
            .map(|m| codec.encrypt_update(m, &mask, &pk, &mut rng))
            .collect();

        let agg = XlaAggregator::new(&rt, codec.ctx.params.clone()).unwrap();
        let via_xla = agg.aggregate(&updates, &alphas).unwrap();
        let via_native = native::aggregate(&updates, &alphas, &codec.ctx.params);

        assert_eq!(via_xla.cts.len(), via_native.cts.len());
        for (a, b) in via_xla.cts.iter().zip(via_native.cts.iter()) {
            assert_eq!(a.c0, b.c0, "c0 limbs differ");
            assert_eq!(a.c1, b.c1, "c1 limbs differ");
            assert!((a.scale - b.scale).abs() < 1e-9);
        }
        for (a, b) in via_xla.plain.iter().zip(via_native.plain.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    /// End-to-end through the kernel: decrypt(xla_aggregate(enc(models)))
    /// equals plain FedAvg.
    #[test]
    fn xla_aggregate_decrypts_to_fedavg() {
        let Some(rt) = runtime() else { return };
        let (codec, mut rng) = setup(&rt);
        let (pk, sk) = codec.ctx.keygen(&mut rng);
        let alphas = [0.25, 0.25, 0.25, 0.25];
        let total = 5000;
        let models: Vec<Vec<f32>> = (0..4)
            .map(|c| (0..total).map(|i| ((i * (c + 1)) as f32 * 0.002).cos()).collect())
            .collect();
        let mask = EncryptionMask::full(total);
        let updates: Vec<_> = models
            .iter()
            .map(|m| codec.encrypt_update(m, &mask, &pk, &mut rng))
            .collect();
        let agg = XlaAggregator::new(&rt, codec.ctx.params.clone()).unwrap();
        let out = agg.aggregate(&updates, &alphas).unwrap();
        let got = codec.decrypt_update(&out, &mask, &sk);
        let expected = native::plain_fedavg(&models, &alphas);
        for j in 0..total {
            assert!(
                (got[j] - expected[j]).abs() < 1e-5,
                "j={j}: {} vs {}",
                got[j],
                expected[j]
            );
        }
    }

    /// More clients than the artifact width (8): grouping path.
    #[test]
    fn client_grouping_beyond_artifact_width() {
        let Some(rt) = runtime() else { return };
        let (codec, mut rng) = setup(&rt);
        let (pk, sk) = codec.ctx.keygen(&mut rng);
        let n_clients = 11;
        let alphas: Vec<f64> = vec![1.0 / n_clients as f64; n_clients];
        let total = 2000;
        let models: Vec<Vec<f32>> = (0..n_clients)
            .map(|c| vec![c as f32; total])
            .collect();
        let mask = EncryptionMask::full(total);
        let updates: Vec<_> = models
            .iter()
            .map(|m| codec.encrypt_update(m, &mask, &pk, &mut rng))
            .collect();
        let agg = XlaAggregator::new(&rt, codec.ctx.params.clone()).unwrap();
        let out = agg.aggregate(&updates, &alphas).unwrap();
        let got = codec.decrypt_update(&out, &mask, &sk);
        let expected = (0..n_clients).map(|c| c as f32).sum::<f32>() / n_clients as f32;
        for j in 0..total {
            assert!((got[j] - expected).abs() < 1e-4, "j={j}: {}", got[j]);
        }
    }
}
