//! Laplace mechanism (Definitions 3.6/3.7, Lemma 3.8 of the paper).
//!
//! Algorithm 1 allows optional local DP noise on the plaintext portion of a
//! selectively-encrypted update; the §3 privacy analysis compares full-DP,
//! random-selection and sensitivity-selection budgets. This module provides
//! the mechanism itself; budget accounting lives in [`crate::privacy`].

use crate::crypto::prng::ChaChaRng;

/// Sample Laplace(0, b) by inverse CDF.
pub fn laplace(rng: &mut ChaChaRng, b: f64) -> f64 {
    assert!(b > 0.0, "scale must be positive");
    // u uniform in (-1/2, 1/2]; x = -b * sign(u) * ln(1 - 2|u|)
    let u = rng.uniform_f64() - 0.5;
    let s = if u >= 0.0 { 1.0 } else { -1.0 };
    -b * s * (1.0 - 2.0 * u.abs()).max(f64::MIN_POSITIVE).ln()
}

/// The Laplace mechanism: adds Laplace(Δf/ε) noise to each coordinate,
/// achieving ε-DP per coordinate (Lemma 3.8).
pub fn laplace_mechanism(rng: &mut ChaChaRng, values: &mut [f32], sensitivity: f64, epsilon: f64) {
    assert!(epsilon > 0.0);
    let b = sensitivity / epsilon;
    for v in values.iter_mut() {
        *v += laplace(rng, b) as f32;
    }
}

/// Add Laplace(b) noise with an explicit scale (the `Noise(b)` call of
/// Algorithm 1).
pub fn add_noise(rng: &mut ChaChaRng, values: &mut [f32], b: f64) {
    for v in values.iter_mut() {
        *v += laplace(rng, b) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laplace_moments() {
        let mut rng = ChaChaRng::from_seed(100, 0);
        let b = 2.0;
        let n = 40_000;
        let samples: Vec<f64> = (0..n).map(|_| laplace(&mut rng, b)).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        // Var[Laplace(b)] = 2 b^2 = 8
        assert!((var - 8.0).abs() < 0.5, "var {var}");
    }

    #[test]
    fn mechanism_perturbs_all_coordinates() {
        let mut rng = ChaChaRng::from_seed(101, 0);
        let mut xs = vec![1.0f32; 64];
        laplace_mechanism(&mut rng, &mut xs, 1.0, 0.5);
        assert!(xs.iter().all(|&x| x != 1.0));
    }

    #[test]
    fn smaller_epsilon_means_more_noise() {
        // Empirical check of the ε↔noise tradeoff.
        let spread = |eps: f64| {
            let mut rng = ChaChaRng::from_seed(102, 0);
            let mut xs = vec![0.0f32; 4096];
            laplace_mechanism(&mut rng, &mut xs, 1.0, eps);
            xs.iter().map(|x| x.abs() as f64).sum::<f64>() / xs.len() as f64
        };
        assert!(spread(0.1) > 5.0 * spread(10.0));
    }
}
