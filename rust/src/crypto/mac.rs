//! Keyed frame authentication for the session wire (DESIGN.md §12).
//!
//! A from-scratch SipHash-2-4 produces the 64-bit truncated tags that
//! authenticate every post-handshake frame, and a small KDF chain derives
//! the key hierarchy distributed out-of-band via the task-key file:
//!
//! ```text
//! task mac_root (32 B, OS entropy, in the task key file)
//!   └─ per-client key   = ChaCha20(root, nonce = client_id)   [derive_client_key]
//!        └─ session key = SipHash-KDF(client key, server nonce) [derive_session_key]
//! ```
//!
//! SipHash is a keyed PRF designed exactly for this setting — short
//! authenticators over untrusted input with a secret key — and is tiny
//! enough to implement from primary sources (the reference test vectors
//! below pin the implementation). The 64-bit tag is deliberate: the wire
//! already rejects malformed frames via CRC, the MAC only has to defeat
//! *online* forgery against a live session, and 2⁻⁶⁴ per-frame forgery
//! probability with a monotone sequence number is far below the session
//! frame budget.

use crate::crypto::prng::ChaChaRng;

/// 256-bit MAC key. Only the first 16 bytes feed SipHash (its native key
/// size); the remaining 16 participate in the session KDF so the full
/// 256 bits of derived entropy matter.
#[derive(Clone, PartialEq, Eq)]
pub struct MacKey(pub [u8; 32]);

impl std::fmt::Debug for MacKey {
    /// Key material must never reach logs or error strings.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "MacKey(..)")
    }
}

#[inline(always)]
fn sipround(v: &mut [u64; 4]) {
    v[0] = v[0].wrapping_add(v[1]);
    v[1] = v[1].rotate_left(13) ^ v[0];
    v[0] = v[0].rotate_left(32);
    v[2] = v[2].wrapping_add(v[3]);
    v[3] = v[3].rotate_left(16) ^ v[2];
    v[0] = v[0].wrapping_add(v[3]);
    v[3] = v[3].rotate_left(21) ^ v[0];
    v[2] = v[2].wrapping_add(v[1]);
    v[1] = v[1].rotate_left(17) ^ v[2];
    v[2] = v[2].rotate_left(32);
}

#[inline(always)]
fn compress(v: &mut [u64; 4], m: u64) {
    v[3] ^= m;
    sipround(v);
    sipround(v);
    v[0] ^= m;
}

/// SipHash-2-4 over the concatenation of `parts` (scatter/gather input so
/// callers never materialize `dir ‖ seq ‖ header ‖ payload ‖ crc`).
pub fn tag64(key: &MacKey, parts: &[&[u8]]) -> u64 {
    let k0 = u64::from_le_bytes(key.0[0..8].try_into().unwrap());
    let k1 = u64::from_le_bytes(key.0[8..16].try_into().unwrap());
    let mut v = [
        k0 ^ 0x736f_6d65_7073_6575,
        k1 ^ 0x646f_7261_6e64_6f6d,
        k0 ^ 0x6c79_6765_6e65_7261,
        k1 ^ 0x7465_6462_7974_6573,
    ];
    let mut buf = [0u8; 8];
    let mut fill = 0usize;
    let mut total = 0u64;
    for part in parts {
        let mut p: &[u8] = part;
        total = total.wrapping_add(p.len() as u64);
        // top up the straddling word first
        if fill > 0 {
            let take = (8 - fill).min(p.len());
            buf[fill..fill + take].copy_from_slice(&p[..take]);
            fill += take;
            p = &p[take..];
            if fill == 8 {
                compress(&mut v, u64::from_le_bytes(buf));
                fill = 0;
            }
        }
        // bulk: whole aligned words straight from the part
        let mut chunks = p.chunks_exact(8);
        for c in &mut chunks {
            compress(&mut v, u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        buf[..rem.len()].copy_from_slice(rem);
        fill = rem.len();
    }
    // final word: remaining bytes plus the total length in the top byte
    let mut last = (total & 0xff) << 56;
    for (i, &b) in buf[..fill].iter().enumerate() {
        last |= (b as u64) << (8 * i);
    }
    compress(&mut v, last);
    v[2] ^= 0xff;
    for _ in 0..4 {
        sipround(&mut v);
    }
    v[0] ^ v[1] ^ v[2] ^ v[3]
}

/// Derive client `client_id`'s long-lived MAC key from the task root key.
/// The derivation is a ChaCha20 stream keyed by the root with the client id
/// as nonce — forward-secure in the root (learning one client key reveals
/// nothing about siblings or the root).
pub fn derive_client_key(root: &[u8; 32], client_id: u64) -> MacKey {
    let mut nonce = [0u8; 12];
    nonce[..8].copy_from_slice(&client_id.to_le_bytes());
    let mut rng = ChaChaRng::new(root, &nonce);
    let mut k = [0u8; 32];
    rng.fill_bytes(&mut k);
    MacKey(k)
}

/// Derive the per-session key from a client key and the server's 16-byte
/// handshake nonce. Domain-separated SipHash-KDF: four tagged blocks, each
/// folding in the nonce, a block index, and the client key's upper half
/// (the bytes SipHash itself never consumes).
pub fn derive_session_key(client_key: &MacKey, nonce: &[u8; 16]) -> MacKey {
    let mut k = [0u8; 32];
    for (i, chunk) in k.chunks_exact_mut(8).enumerate() {
        let t = tag64(
            client_key,
            &[
                b"fedml-he/session-kdf",
                nonce,
                &[i as u8],
                &client_key.0[16..],
            ],
        );
        chunk.copy_from_slice(&t.to_le_bytes());
    }
    MacKey(k)
}

/// Challenge/response proof tag: the CHALLENGE_RESP payload carries this
/// over (nonce, client id) under the freshly derived session key, proving
/// possession of the client key without ever sending key bytes.
pub fn handshake_tag(session_key: &MacKey, nonce: &[u8; 16], client_id: u64) -> u64 {
    tag64(
        session_key,
        &[b"fedml-he/hello", nonce, &client_id.to_le_bytes()],
    )
}

/// Per-frame authenticator: direction byte (1 = client→server, 2 =
/// server→client, so reflected frames never verify) ‖ the session-monotone
/// auth sequence ‖ the full frame header ‖ payload ‖ CRC.
pub fn frame_tag(key: &MacKey, dir: u8, auth_seq: u32, hdr: &[u8], payload: &[u8], crc: u32) -> u64 {
    tag64(
        key,
        &[
            &[dir],
            &auth_seq.to_le_bytes(),
            hdr,
            payload,
            &crc.to_le_bytes(),
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ref_key() -> MacKey {
        let mut k = [0u8; 32];
        for (i, b) in k.iter_mut().enumerate().take(16) {
            *b = i as u8;
        }
        MacKey(k)
    }

    #[test]
    fn siphash24_reference_vectors() {
        // Aumasson & Bernstein's reference vectors: key 00..0f, message
        // 00,01,02,... of increasing length.
        let key = ref_key();
        let msg: Vec<u8> = (0..8u8).collect();
        assert_eq!(tag64(&key, &[&[]]), 0x726f_db47_dd0e_0e31);
        assert_eq!(tag64(&key, &[&msg[..1]]), 0x74f8_39c5_93dc_67fd);
        assert_eq!(tag64(&key, &[&msg[..7]]), 0xab02_00f5_8b01_d137);
        assert_eq!(tag64(&key, &[&msg[..8]]), 0x93f5_f579_9a93_2462);
    }

    #[test]
    fn scattered_parts_match_contiguous_input() {
        let key = ref_key();
        let data: Vec<u8> = (0..64u8).map(|i| i.wrapping_mul(37)).collect();
        let whole = tag64(&key, &[&data]);
        assert_eq!(tag64(&key, &[&data[..1], &data[1..]]), whole);
        assert_eq!(tag64(&key, &[&data[..5], &data[5..13], &data[13..]]), whole);
        let singles: Vec<&[u8]> = data.chunks(1).collect();
        assert_eq!(tag64(&key, &singles), whole);
        // part boundaries are NOT authenticated structure: only bytes are
        assert_ne!(tag64(&key, &[&data[..32]]), whole);
    }

    #[test]
    fn key_hierarchy_separates_clients_and_sessions() {
        let root = [7u8; 32];
        let a = derive_client_key(&root, 0);
        let b = derive_client_key(&root, 1);
        assert_ne!(a.0, b.0);
        // deterministic per (root, id)
        assert_eq!(derive_client_key(&root, 0).0, a.0);
        let n1 = [1u8; 16];
        let n2 = [2u8; 16];
        let s1 = derive_session_key(&a, &n1);
        let s2 = derive_session_key(&a, &n2);
        assert_ne!(s1.0, s2.0, "fresh nonce must give a fresh session key");
        assert_ne!(s1.0, a.0);
        assert_ne!(
            handshake_tag(&s1, &n1, 0),
            handshake_tag(&derive_session_key(&b, &n1), &n1, 0)
        );
    }

    #[test]
    fn frame_tags_bind_direction_sequence_and_content() {
        let key = ref_key();
        let hdr = [0x11u8; 28];
        let payload = [0x22u8; 40];
        let t = frame_tag(&key, 1, 7, &hdr, &payload, 0xdead_beef);
        assert_ne!(t, frame_tag(&key, 2, 7, &hdr, &payload, 0xdead_beef));
        assert_ne!(t, frame_tag(&key, 1, 8, &hdr, &payload, 0xdead_beef));
        assert_ne!(t, frame_tag(&key, 1, 7, &hdr, &payload, 0xdead_bee0));
        let mut p2 = payload;
        p2[0] ^= 1;
        assert_ne!(t, frame_tag(&key, 1, 7, &hdr, &p2, 0xdead_beef));
        assert_eq!(t, frame_tag(&key, 1, 7, &hdr, &payload, 0xdead_beef));
    }

    #[test]
    fn debug_never_prints_key_bytes() {
        let k = MacKey([0xabu8; 32]);
        let s = format!("{k:?}");
        assert!(!s.contains("ab") && !s.contains("171"), "leaked: {s}");
    }
}
