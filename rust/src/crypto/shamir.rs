//! Shamir secret sharing over the prime field GF(p), p = 2^61 - 1 (Mersenne).
//!
//! Used by the key-management layer (Appendix B): the key authority can
//! escrow a CKKS secret key as t-of-n shares so that a quorum of clients can
//! reconstruct it after catastrophic dropout, and the threshold-HE setup uses
//! it to back up per-party key shares. Secrets larger than the field are
//! split into 32-bit chunks, each shared independently.

use crate::crypto::prng::ChaChaRng;

/// Field modulus: the Mersenne prime 2^61 - 1.
pub const P: u64 = (1u64 << 61) - 1;

#[inline]
fn fadd(a: u64, b: u64) -> u64 {
    let s = a + b; // < 2^62, no overflow
    if s >= P {
        s - P
    } else {
        s
    }
}

#[inline]
fn fsub(a: u64, b: u64) -> u64 {
    if a >= b {
        a - b
    } else {
        a + P - b
    }
}

#[inline]
fn fmul(a: u64, b: u64) -> u64 {
    ((a as u128 * b as u128) % P as u128) as u64
}

/// Modular inverse by Fermat's little theorem.
fn finv(a: u64) -> u64 {
    assert!(a % P != 0, "no inverse of 0");
    // a^(p-2) mod p
    let mut base = a % P;
    let mut exp = P - 2;
    let mut acc = 1u64;
    while exp > 0 {
        if exp & 1 == 1 {
            acc = fmul(acc, base);
        }
        base = fmul(base, base);
        exp >>= 1;
    }
    acc
}

/// One share of a field element: the point (x, y) on the polynomial.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Share {
    pub x: u64,
    pub y: u64,
}

/// Split `secret` (< P) into `n` shares with threshold `t` (any `t` shares
/// reconstruct, fewer reveal nothing).
pub fn split(secret: u64, t: usize, n: usize, rng: &mut ChaChaRng) -> Vec<Share> {
    assert!(t >= 1 && t <= n, "need 1 <= t <= n");
    assert!(secret < P, "secret must be < field modulus");
    // Random degree-(t-1) polynomial with constant term = secret.
    let mut coeffs = vec![secret];
    for _ in 1..t {
        coeffs.push(rng.uniform_u64(P));
    }
    (1..=n as u64)
        .map(|x| {
            // Horner evaluation.
            let mut y = 0u64;
            for &c in coeffs.iter().rev() {
                y = fadd(fmul(y, x), c);
            }
            Share { x, y }
        })
        .collect()
}

/// Reconstruct the secret from at least `t` distinct shares via Lagrange
/// interpolation at x = 0.
pub fn reconstruct(shares: &[Share]) -> u64 {
    let mut secret = 0u64;
    for (i, si) in shares.iter().enumerate() {
        let mut num = 1u64;
        let mut den = 1u64;
        for (j, sj) in shares.iter().enumerate() {
            if i == j {
                continue;
            }
            num = fmul(num, sj.x % P);
            den = fmul(den, fsub(sj.x % P, si.x % P));
        }
        let li = fmul(num, finv(den));
        secret = fadd(secret, fmul(si.y, li));
    }
    secret
}

/// Share an arbitrary byte string: each 4-byte chunk becomes a field element.
/// Returns per-party share vectors (party k gets `out[k]`).
pub fn split_bytes(data: &[u8], t: usize, n: usize, rng: &mut ChaChaRng) -> Vec<Vec<Share>> {
    let mut per_party: Vec<Vec<Share>> = vec![Vec::new(); n];
    for chunk in data.chunks(4) {
        let mut word = [0u8; 4];
        word[..chunk.len()].copy_from_slice(chunk);
        let secret = u32::from_le_bytes(word) as u64;
        let shares = split(secret, t, n, rng);
        for (k, s) in shares.into_iter().enumerate() {
            per_party[k].push(s);
        }
    }
    per_party
}

/// Reconstruct a byte string of length `len` from per-party share vectors.
pub fn reconstruct_bytes(parties: &[&[Share]], len: usize) -> Vec<u8> {
    let chunks = parties[0].len();
    assert!(parties.iter().all(|p| p.len() == chunks));
    let mut out = Vec::with_capacity(len);
    for c in 0..chunks {
        let shares: Vec<Share> = parties.iter().map(|p| p[c]).collect();
        let word = reconstruct(&shares) as u32;
        out.extend_from_slice(&word.to_le_bytes());
    }
    out.truncate(len);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_sanity() {
        assert_eq!(fmul(finv(12345), 12345), 1);
        assert_eq!(fadd(P - 1, 1), 0);
        assert_eq!(fsub(0, 1), P - 1);
    }

    #[test]
    fn split_reconstruct_roundtrip() {
        let mut rng = ChaChaRng::from_seed(1, 0);
        for (t, n) in [(1usize, 1usize), (2, 3), (3, 5), (5, 5)] {
            let secret = rng.uniform_u64(P);
            let shares = split(secret, t, n, &mut rng);
            // any t-subset reconstructs
            assert_eq!(reconstruct(&shares[..t]), secret);
            assert_eq!(reconstruct(&shares[n - t..]), secret);
            // all shares also reconstruct
            assert_eq!(reconstruct(&shares), secret);
        }
    }

    #[test]
    fn fewer_than_t_shares_do_not_reconstruct() {
        let mut rng = ChaChaRng::from_seed(2, 0);
        let secret = 0xDEAD_BEEFu64;
        let shares = split(secret, 3, 5, &mut rng);
        // With only 2 of 3 required shares, Lagrange gives a wrong value with
        // overwhelming probability (information-theoretically independent).
        assert_ne!(reconstruct(&shares[..2]), secret);
    }

    #[test]
    fn bytes_roundtrip() {
        let mut rng = ChaChaRng::from_seed(3, 0);
        let data: Vec<u8> = (0u8..=255).cycle().take(1001).collect();
        let parties = split_bytes(&data, 2, 4, &mut rng);
        let rec = reconstruct_bytes(&[&parties[1], &parties[3]], data.len());
        assert_eq!(rec, data);
    }

    /// Property sweep: random (t, n, secret) combinations all roundtrip.
    #[test]
    fn property_sweep() {
        let mut rng = ChaChaRng::from_seed(4, 0);
        for _ in 0..50 {
            let n = 1 + rng.uniform_usize(8);
            let t = 1 + rng.uniform_usize(n);
            let secret = rng.uniform_u64(P);
            let mut shares = split(secret, t, n, &mut rng);
            rng.shuffle(&mut shares);
            assert_eq!(reconstruct(&shares[..t]), secret);
        }
    }
}
