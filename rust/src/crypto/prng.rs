//! ChaCha20-based cryptographically secure pseudo-random number generator.
//!
//! Implemented from scratch (RFC 8439 block function). Used for all secret
//! sampling in the CKKS substrate: uniform ring elements, ternary secrets,
//! centered-binomial errors. Deterministic seeding is supported for tests and
//! reproducible experiments; [`ChaChaRng::from_os_entropy`] seeds from
//! `/dev/urandom` for real key generation.

/// ChaCha20 quarter round.
#[inline(always)]
fn quarter(state: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize) {
    state[a] = state[a].wrapping_add(state[b]);
    state[d] = (state[d] ^ state[a]).rotate_left(16);
    state[c] = state[c].wrapping_add(state[d]);
    state[b] = (state[b] ^ state[c]).rotate_left(12);
    state[a] = state[a].wrapping_add(state[b]);
    state[d] = (state[d] ^ state[a]).rotate_left(8);
    state[c] = state[c].wrapping_add(state[d]);
    state[b] = (state[b] ^ state[c]).rotate_left(7);
}

/// Run the 20-round ChaCha block function on `input`, producing 64 bytes of
/// keystream as 16 little-endian u32 words.
fn chacha20_block(input: &[u32; 16]) -> [u32; 16] {
    let mut x = *input;
    for _ in 0..10 {
        // column rounds
        quarter(&mut x, 0, 4, 8, 12);
        quarter(&mut x, 1, 5, 9, 13);
        quarter(&mut x, 2, 6, 10, 14);
        quarter(&mut x, 3, 7, 11, 15);
        // diagonal rounds
        quarter(&mut x, 0, 5, 10, 15);
        quarter(&mut x, 1, 6, 11, 12);
        quarter(&mut x, 2, 7, 8, 13);
        quarter(&mut x, 3, 4, 9, 14);
    }
    for i in 0..16 {
        x[i] = x[i].wrapping_add(input[i]);
    }
    x
}

/// A ChaCha20 keystream RNG.
#[derive(Debug, Clone)]
pub struct ChaChaRng {
    state: [u32; 16],
    buf: [u32; 16],
    /// Next unread word in `buf` (16 = exhausted).
    idx: usize,
}

const SIGMA: [u32; 4] = [0x6170_7865, 0x3320_646e, 0x7962_2d32, 0x6b20_6574];

impl ChaChaRng {
    /// Construct from a 32-byte key and 12-byte nonce.
    pub fn new(key: &[u8; 32], nonce: &[u8; 12]) -> Self {
        let mut state = [0u32; 16];
        state[..4].copy_from_slice(&SIGMA);
        for i in 0..8 {
            state[4 + i] = u32::from_le_bytes(key[4 * i..4 * i + 4].try_into().unwrap());
        }
        state[12] = 0; // block counter
        for i in 0..3 {
            state[13 + i] = u32::from_le_bytes(nonce[4 * i..4 * i + 4].try_into().unwrap());
        }
        ChaChaRng {
            state,
            buf: [0; 16],
            idx: 16,
        }
    }

    /// Deterministic seeding for tests/experiments: expands a u64 seed and a
    /// stream id into the key/nonce.
    pub fn from_seed(seed: u64, stream: u64) -> Self {
        let mut key = [0u8; 32];
        key[..8].copy_from_slice(&seed.to_le_bytes());
        key[8..16].copy_from_slice(&seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).to_le_bytes());
        key[16..24].copy_from_slice(&stream.to_le_bytes());
        key[24..32].copy_from_slice(&stream.wrapping_add(0xD1B5_4A32_D192_ED03).to_le_bytes());
        let nonce = [0u8; 12];
        ChaChaRng::new(&key, &nonce)
    }

    /// Fork an independent child stream: the child's 32-byte key is drawn
    /// from this rng's keystream and its nonce encodes `stream`. Forking is
    /// deterministic given the parent state, and children with distinct
    /// `stream` ids (or distinct fork points) produce independent
    /// keystreams — the parallel client codec forks one child per ciphertext
    /// chunk in chunk order, so chunk results are identical no matter which
    /// worker thread encrypts them.
    pub fn fork(&mut self, stream: u64) -> ChaChaRng {
        let mut key = [0u8; 32];
        self.fill_bytes(&mut key);
        let mut nonce = [0u8; 12];
        nonce[..8].copy_from_slice(&stream.to_le_bytes());
        ChaChaRng::new(&key, &nonce)
    }

    /// Seed from the OS entropy pool.
    pub fn from_os_entropy() -> std::io::Result<Self> {
        use std::io::Read;
        let mut key = [0u8; 32];
        let mut nonce = [0u8; 12];
        let mut f = std::fs::File::open("/dev/urandom")?;
        f.read_exact(&mut key)?;
        f.read_exact(&mut nonce)?;
        Ok(ChaChaRng::new(&key, &nonce))
    }

    fn refill(&mut self) {
        self.buf = chacha20_block(&self.state);
        self.state[12] = self.state[12].wrapping_add(1);
        if self.state[12] == 0 {
            self.state[13] = self.state[13].wrapping_add(1);
        }
        self.idx = 0;
    }

    /// Next 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        if self.idx >= 16 {
            self.refill();
        }
        let v = self.buf[self.idx];
        self.idx += 1;
        v
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        (self.next_u32() as u64) | ((self.next_u32() as u64) << 32)
    }

    /// Uniform in `[0, bound)` by rejection sampling (unbiased).
    pub fn uniform_u64(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        // Largest multiple of `bound` that fits in u64.
        let zone = u64::MAX - (u64::MAX % bound);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % bound;
            }
        }
    }

    /// Uniform usize in `[0, bound)`.
    pub fn uniform_usize(&mut self, bound: usize) -> usize {
        self.uniform_u64(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    pub fn uniform_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller.
    pub fn normal_f64(&mut self) -> f64 {
        let u1 = loop {
            let u = self.uniform_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.uniform_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Ternary sample in {-1, 0, 1} with probabilities (1/4, 1/2, 1/4) —
    /// the standard CKKS secret/ephemeral distribution.
    pub fn ternary(&mut self) -> i64 {
        match self.next_u32() & 3 {
            0 => -1,
            1 => 1,
            _ => 0,
        }
    }

    /// Centered binomial with parameter `k` (variance `k/2`); `k = 21` gives
    /// the σ≈3.2 discrete-Gaussian-equivalent error used by RNS-CKKS stacks.
    pub fn cbd(&mut self, k: u32) -> i64 {
        let mut acc = 0i64;
        let mut remaining = k;
        while remaining > 0 {
            let take = remaining.min(32);
            let a = self.next_u32() & (((1u64 << take) - 1) as u32);
            let b = self.next_u32() & (((1u64 << take) - 1) as u32);
            acc += a.count_ones() as i64 - b.count_ones() as i64;
            remaining -= take;
        }
        acc
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.uniform_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fill a byte buffer with keystream.
    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        let mut i = 0;
        while i < out.len() {
            let w = self.next_u32().to_le_bytes();
            let n = (out.len() - i).min(4);
            out[i..i + n].copy_from_slice(&w[..n]);
            i += n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// RFC 8439 §2.3.2 test vector for the ChaCha20 block function.
    #[test]
    fn rfc8439_block_vector() {
        let mut state = [0u32; 16];
        state[..4].copy_from_slice(&SIGMA);
        let key: Vec<u8> = (0u8..32).collect();
        for i in 0..8 {
            state[4 + i] = u32::from_le_bytes(key[4 * i..4 * i + 4].try_into().unwrap());
        }
        state[12] = 1;
        state[13] = 0x0900_0000;
        state[14] = 0x4a00_0000;
        state[15] = 0x0000_0000;
        let out = chacha20_block(&state);
        let expected: [u32; 16] = [
            0xe4e7f110, 0x15593bd1, 0x1fdd0f50, 0xc47120a3, 0xc7f4d1c7, 0x0368c033, 0x9aaa2204,
            0x4e6cd4c3, 0x466482d2, 0x09aa9f07, 0x05d7c214, 0xa2028bd9, 0xd19c12b5, 0xb94e16de,
            0xe883d0cb, 0x4e3c50a2,
        ];
        assert_eq!(out, expected);
    }

    #[test]
    fn determinism_and_streams() {
        let mut a = ChaChaRng::from_seed(42, 0);
        let mut b = ChaChaRng::from_seed(42, 0);
        let mut c = ChaChaRng::from_seed(42, 1);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn uniform_bound_respected() {
        let mut rng = ChaChaRng::from_seed(7, 7);
        for bound in [1u64, 2, 3, 1000, 1 << 31, (1 << 31) - 1] {
            for _ in 0..200 {
                assert!(rng.uniform_u64(bound) < bound);
            }
        }
    }

    #[test]
    fn uniform_f64_in_unit_interval() {
        let mut rng = ChaChaRng::from_seed(1, 2);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = rng.uniform_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn ternary_distribution() {
        let mut rng = ChaChaRng::from_seed(3, 4);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            match rng.ternary() {
                -1 => counts[0] += 1,
                0 => counts[1] += 1,
                1 => counts[2] += 1,
                _ => unreachable!(),
            }
        }
        // ~7.5k, 15k, 7.5k
        assert!((counts[0] as f64 - 7500.0).abs() < 500.0);
        assert!((counts[1] as f64 - 15000.0).abs() < 700.0);
        assert!((counts[2] as f64 - 7500.0).abs() < 500.0);
    }

    #[test]
    fn cbd_moments() {
        let mut rng = ChaChaRng::from_seed(9, 9);
        let k = 21;
        let n = 20_000;
        let samples: Vec<i64> = (0..n).map(|_| rng.cbd(k)).collect();
        let mean = samples.iter().sum::<i64>() as f64 / n as f64;
        let var = samples.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.1, "mean {mean}");
        // variance k/2 = 10.5
        assert!((var - 10.5).abs() < 0.6, "var {var}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = ChaChaRng::from_seed(11, 0);
        let n = 20_000;
        let samples: Vec<f64> = (0..n).map(|_| rng.normal_f64()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05);
        assert!((var - 1.0).abs() < 0.05);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = ChaChaRng::from_seed(5, 5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_is_deterministic_and_stream_separated() {
        let mut a = ChaChaRng::from_seed(6, 0);
        let mut b = ChaChaRng::from_seed(6, 0);
        let mut c1 = a.fork(0);
        let mut c2 = b.fork(0);
        assert_eq!(c1.next_u64(), c2.next_u64());
        // parents advanced identically
        assert_eq!(a.next_u64(), b.next_u64());
        // distinct stream ids at the same fork point differ
        let mut p = ChaChaRng::from_seed(6, 0);
        let mut q = ChaChaRng::from_seed(6, 0);
        let mut d1 = p.fork(1);
        let mut d2 = q.fork(2);
        assert_ne!(d1.next_u64(), d2.next_u64());
        // children differ from the parent stream
        assert_ne!(c1.next_u64(), a.next_u64());
    }

    #[test]
    fn os_entropy_seeds() {
        let mut a = ChaChaRng::from_os_entropy().unwrap();
        let mut b = ChaChaRng::from_os_entropy().unwrap();
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
