//! Cryptographic substrates built from scratch: a ChaCha20-based CSPRNG,
//! Shamir secret sharing over a prime field (used by the threshold-HE key
//! management of Appendix B), the Laplace mechanism for the optional
//! local differential-privacy noise of Algorithm 1, and the SipHash-2-4
//! frame-authentication keys/tags of the hardened session wire
//! (DESIGN.md §12).

pub mod dp;
pub mod mac;
pub mod prng;
pub mod shamir;
