//! Cryptographic substrates built from scratch: a ChaCha20-based CSPRNG,
//! Shamir secret sharing over a prime field (used by the threshold-HE key
//! management of Appendix B), and the Laplace mechanism for the optional
//! local differential-privacy noise of Algorithm 1.

pub mod dp;
pub mod prng;
pub mod shamir;
