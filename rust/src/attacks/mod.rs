//! Privacy attacks used to evaluate the selection defense (§4.2.2):
//! DLG gradient inversion on image models (Fig. 9) and embedding-gradient
//! token recovery on the transformer (Fig. 10 analog), plus the similarity
//! metrics that score them.

pub mod dlg;
pub mod metrics;
pub mod nlp;
