//! Privacy attacks used to evaluate the selection defense (§4.2.2):
//! DLG gradient inversion on image models (Fig. 9) and embedding-gradient
//! token recovery on the transformer (Fig. 10 analog), plus the similarity
//! metrics that score them — and the adversarial *transport* harness
//! ([`transport`]) that drives live authenticated sessions through
//! scripted wire adversaries (DESIGN.md §12).

pub mod dlg;
pub mod metrics;
pub mod nlp;
pub mod transport;
