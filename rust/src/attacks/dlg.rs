//! Gradient-inversion attack driver (DLG, Zhu et al. 2019 — Fig. 9).
//!
//! The adversarial server observes a client's *visible* gradient — only the
//! unencrypted coordinates under Selective Parameter Encryption — and
//! descends a gradient-matching loss on dummy data. The optimization step is
//! an AOT JAX graph (`<model>_dlg`); this module drives restarts and
//! iterations from Rust and scores recoveries with [`super::metrics`].

use super::metrics::{similarity, Similarity};
use crate::crypto::prng::ChaChaRng;
use crate::he_agg::EncryptionMask;
use crate::runtime::executor::{Arg, Runtime};

/// Attack configuration.
#[derive(Debug, Clone)]
pub struct DlgConfig {
    pub iters: usize,
    pub restarts: usize,
    pub lr: f32,
}

impl Default for DlgConfig {
    fn default() -> Self {
        DlgConfig {
            iters: 60,
            restarts: 3,
            lr: 0.05,
        }
    }
}

/// Result of an attack run.
#[derive(Debug, Clone)]
pub struct DlgOutcome {
    /// Best recovered image (by final matching loss), flat CHW.
    pub recovered: Vec<f32>,
    pub final_match_loss: f32,
    /// Similarity of the recovery vs the victim image.
    pub similarity: Similarity,
}

/// Run DLG against a victim gradient.
///
/// * `model` — "lenet" or "cnn" (models with a `_dlg` artifact);
/// * `victim_x` — the ground-truth image (for scoring only);
/// * `target_grad` — the full gradient the client computed;
/// * `mask` — the encryption mask; masked coordinates are zeroed in the
///   attacker's view (it cannot see ciphertext contents — Theorem 3.9).
pub fn run_dlg(
    rt: &Runtime,
    model: &str,
    params: &[f32],
    victim_x: &[f32],
    target_grad: &[f32],
    mask: &EncryptionMask,
    cfg: &DlgConfig,
    rng: &mut ChaChaRng,
) -> anyhow::Result<DlgOutcome> {
    let meta = rt
        .manifest
        .models
        .get(model)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{model}'"))?;
    let num_classes = meta.num_classes;
    let x_len: usize = meta.input_shape.iter().product();
    anyhow::ensure!(victim_x.len() == x_len, "victim image length mismatch");
    let graph = format!("{model}_dlg");

    // Attacker's view: visible gradient with protected coordinates zeroed,
    // and a float mask that also zeroes the dummy gradient inside the graph.
    let dense = mask.to_dense();
    let mask_f: Vec<f32> = dense.iter().map(|&b| if b { 0.0 } else { 1.0 }).collect();
    let visible_grad: Vec<f32> = target_grad
        .iter()
        .zip(dense.iter())
        .map(|(&g, &enc)| if enc { 0.0 } else { g })
        .collect();

    let mut x_dims = vec![1i64];
    x_dims.extend(meta.input_shape.iter().map(|&d| d as i64));

    let mut best: Option<(f32, Vec<f32>)> = None;
    for _ in 0..cfg.restarts {
        let mut dx: Vec<f32> = (0..x_len).map(|_| rng.normal_f64() as f32 * 0.5).collect();
        let mut dy: Vec<f32> = vec![0.0; num_classes];
        let mut last_loss = f32::INFINITY;
        for _ in 0..cfg.iters {
            let out = rt.execute(
                &graph,
                &[
                    Arg::F32(params, vec![params.len() as i64]),
                    Arg::F32(&visible_grad, vec![visible_grad.len() as i64]),
                    Arg::F32(&mask_f, vec![mask_f.len() as i64]),
                    Arg::F32(&dx, x_dims.clone()),
                    Arg::F32(&dy, vec![1, num_classes as i64]),
                    Arg::ScalarF32(cfg.lr),
                ],
            )?;
            dx = out[0].to_vec::<f32>()?;
            dy = out[1].to_vec::<f32>()?;
            last_loss = out[2].to_vec::<f32>()?[0];
        }
        if best.as_ref().map(|(l, _)| last_loss < *l).unwrap_or(true) {
            best = Some((last_loss, dx));
        }
    }
    let (final_match_loss, recovered) = best.unwrap();
    let channels = meta.input_shape.first().copied().unwrap_or(1);
    Ok(DlgOutcome {
        similarity: similarity(victim_x, &recovered, channels),
        recovered,
        final_match_loss,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::data::synthetic_images;
    use std::path::PathBuf;

    fn runtime() -> Option<Runtime> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Runtime::new(dir).unwrap())
    }

    /// The Fig. 9 qualitative claim: unprotected gradients leak a lot more
    /// than top-10%-protected gradients.
    #[test]
    fn selective_protection_degrades_recovery() {
        let Some(rt) = runtime() else { return };
        let model = "lenet";
        let params = rt.manifest.load_init_params(model).unwrap();
        let d = synthetic_images(0, 4, (1, 28, 28), 10, 0.9, 31);
        let (x, y) = d.batch(0, 1);

        // victim gradient on the single sample via the grad artifact —
        // batch is fixed at 32, so replicate the sample (gradient direction
        // is identical for replicated samples).
        let (xb, yb) = {
            let mut xb = Vec::new();
            let mut yb = Vec::new();
            for _ in 0..rt.manifest.train_batch {
                xb.extend_from_slice(&x);
                yb.extend_from_slice(&y);
            }
            (xb, yb)
        };
        let out = rt
            .execute(
                "lenet_grad",
                &[
                    Arg::F32(&params, vec![params.len() as i64]),
                    Arg::F32(&xb, vec![rt.manifest.train_batch as i64, 1, 28, 28]),
                    Arg::I32(&yb, vec![rt.manifest.train_batch as i64]),
                ],
            )
            .unwrap();
        let grad = out[0].to_vec::<f32>().unwrap();

        // sensitivity-based mask from the victim's own data distribution
        let sens_out = rt
            .execute(
                "lenet_sens",
                &[
                    Arg::F32(&params, vec![params.len() as i64]),
                    Arg::F32(
                        &d.batch(0, rt.manifest.sens_batch).0,
                        vec![rt.manifest.sens_batch as i64, 1, 28, 28],
                    ),
                    Arg::I32(
                        &d.batch(0, rt.manifest.sens_batch).1,
                        vec![rt.manifest.sens_batch as i64],
                    ),
                ],
            )
            .unwrap();
        let sens = sens_out[0].to_vec::<f32>().unwrap();

        let cfg = DlgConfig {
            iters: 120,
            restarts: 2,
            lr: 0.05,
        };
        let mut rng = ChaChaRng::from_seed(5, 0);
        let open = run_dlg(
            &rt,
            model,
            &params,
            &x,
            &grad,
            &EncryptionMask::empty(params.len()),
            &cfg,
            &mut rng,
        )
        .unwrap();
        let mut rng = ChaChaRng::from_seed(5, 0);
        let protected = run_dlg(
            &rt,
            model,
            &params,
            &x,
            &grad,
            &EncryptionMask::top_p(&sens, 0.5),
            &cfg,
            &mut rng,
        )
        .unwrap();

        // Recovery quality: with full gradient visibility the attack gets
        // substantially closer to the victim image than when the top-50%
        // sensitive coordinates are encrypted. (Matching loss itself is not
        // comparable across masks — it sums over fewer visible terms.)
        eprintln!(
            "open: mse {:.4} ssim {:.4} | protected: mse {:.4} ssim {:.4}",
            open.similarity.mse,
            open.similarity.ssim,
            protected.similarity.mse,
            protected.similarity.ssim
        );
        assert!(
            open.similarity.mse < protected.similarity.mse,
            "open mse {} vs protected mse {}",
            open.similarity.mse,
            protected.similarity.mse
        );
    }
}
