//! Adversarial transport harness (DESIGN.md §12): scripted adversaries
//! driving **live** session rounds over loopback TCP, under `--wire-auth
//! mac` semantics, asserting the two properties the hardened wire claims:
//!
//! 1. **Integrity**: forged identities, replayed frames, duplicate HELLOs
//!    and corrupted bytes are rejected (with the right counters bumped) and
//!    the honest participants' aggregate is **bitwise identical** to a
//!    fault-free reference computed locally from the same seeds.
//! 2. **Loud failure**: when an adversary does damage the wire cannot mask
//!    (disconnect storms, a cherry-picking server), the round either seals
//!    with correct straggler/reject accounting or the deficit is visible to
//!    every honest client (`alpha_mass` rides the authenticated preamble).
//!
//! The comparisons lean on two facts proved elsewhere in the crate:
//! ciphertext accumulation is exact modular `u64` arithmetic (commutative),
//! and the plaintext-remainder fold sorts buffered arrivals by client id
//! before summing — so the aggregate is independent of wire arrival order
//! and `==` against a locally built reference is sound. Equal per-client
//! FedAvg weights keep `Σ α` order-independent too.
//!
//! What no scenario can show broken — and §12's threat matrix argues — is
//! confidentiality: the server (honest or malicious) only ever holds
//! ciphertexts plus the deliberately-plaintext remainder; the secret key
//! never crosses the wire, so "read the updates" is not an available move.
//! The harness plays the key-holder only to *evaluate* outcomes.
//!
//! Scenarios run standalone via [`run_all`] (the `adversarial_transport`
//! example and the CI smoke job) and the fast ones double as unit tests.

use std::io::BufReader;
use std::net::TcpStream;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::agg_engine::{Arrival, EngineConfig, StreamStats, StreamingAggregator};
use crate::ckks::{CkksContext, CtWire, PublicKey, SecretKey};
use crate::crypto::mac::derive_client_key;
use crate::crypto::prng::ChaChaRng;
use crate::he_agg::{EncryptedUpdate, EncryptionMask, SelectiveCodec};
use crate::obs::metrics;
use crate::transport::frame::{
    encode_challenge_resp, encode_hello, read_frame_into, write_frame, FrameKind, CONTROL_ROUND,
};
use crate::transport::{
    ChaosConfig, ClientSession, DownBegin, IntakeConfig, SessionHub, SessionOpts, UpdateShape,
};

/// Outcome of one scripted scenario.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    pub name: &'static str,
    pub passed: bool,
    /// Human-readable evidence (counters, set memberships) on pass; the
    /// failure message otherwise.
    pub detail: String,
}

/// Shared CKKS/task fixture: small ring, a selective mask with a real
/// plaintext remainder (both aggregation paths exercised), one key pair.
struct Fixture {
    ctx: CkksContext,
    codec: SelectiveCodec,
    pk: PublicKey,
    sk: SecretKey,
    mask: EncryptionMask,
    shape: UpdateShape,
    total: usize,
}

fn fixture() -> Fixture {
    let ctx = CkksContext::new(256, 3, 30).expect("harness CKKS params");
    let codec = SelectiveCodec::new(ctx.clone());
    let mut rng = ChaChaRng::from_seed(7, 7);
    let (pk, sk) = ctx.keygen(&mut rng);
    let total = 500usize;
    let sens: Vec<f32> = (0..total).map(|i| ((i * 13) % 97) as f32).collect();
    let mask = EncryptionMask::top_p(&sens, 0.5);
    let shape = UpdateShape::for_round(&ctx, &mask);
    Fixture { ctx, codec, pk, sk, mask, shape, total }
}

/// Deterministic per-client model (pure function of the id).
fn client_model(total: usize, id: u64) -> Vec<f32> {
    (0..total).map(|i| ((i as u64 + id * 31) as f32 * 0.003).cos()).collect()
}

/// Deterministic per-client encrypted update: same id + same seed = same
/// ciphertext bytes, whether built wire-side or reference-side.
fn encrypt_client_update(
    codec: &SelectiveCodec,
    pk: &PublicKey,
    mask: &EncryptionMask,
    total: usize,
    id: u64,
) -> EncryptedUpdate {
    let model = client_model(total, id);
    let mut rng = ChaChaRng::from_seed(1000 + id, 0);
    codec.encrypt_update(&model, mask, pk, &mut rng)
}

/// Fault-free reference aggregate of `ids` drawn from a cohort of
/// `cohort` clients (equal FedAvg weights `1/cohort` each).
fn reference_agg(
    fx: &Fixture,
    ids: &[u64],
    cohort: usize,
) -> anyhow::Result<(EncryptedUpdate, StreamStats)> {
    let alpha = 1.0 / cohort as f64;
    let arrivals: Vec<Arrival> = ids
        .iter()
        .enumerate()
        .map(|(k, &id)| Arrival {
            client: id,
            alpha,
            arrival_secs: 0.001 * (k as f64 + 1.0),
            update: Arc::new(encrypt_client_update(&fx.codec, &fx.pk, &fx.mask, fx.total, id)),
        })
        .collect();
    StreamingAggregator::new(&fx.ctx.params, EngineConfig::default())
        .aggregate_with_mask(arrivals, Some(&fx.mask))
}

/// Seal a wire round's arrivals with the same engine the reference uses.
fn wire_agg(
    fx: &Fixture,
    arrivals: Vec<Arrival>,
) -> anyhow::Result<(EncryptedUpdate, StreamStats)> {
    StreamingAggregator::new(&fx.ctx.params, EngineConfig::default())
        .aggregate_with_mask(arrivals, Some(&fx.mask))
}

/// The key-holder's view: decrypt and renormalize by the accepted weight
/// mass (the same arithmetic as the coordinator's decrypt+apply phase).
fn renormalized_global(fx: &Fixture, agg: &EncryptedUpdate, alpha_mass: f64) -> Vec<f32> {
    let mut g = fx.codec.decrypt_update(agg, &fx.mask, &fx.sk);
    if (alpha_mass - 1.0).abs() > 1e-12 {
        for v in g.iter_mut() {
            *v = (*v as f64 / alpha_mass) as f32;
        }
    }
    g
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn updates_bitwise_eq(a: &EncryptedUpdate, b: &EncryptedUpdate) -> bool {
    a.total == b.total
        && a.plain.len() == b.plain.len()
        && a.plain.iter().zip(&b.plain).all(|(x, y)| x.to_bits() == y.to_bits())
        && a.cts.len() == b.cts.len()
        && a.cts.iter().zip(&b.cts).all(|(x, y)| x.c0 == y.c0 && x.c1 == y.c1)
}

fn sorted_ids(arrivals: &[Arrival]) -> Vec<u64> {
    let mut ids: Vec<u64> = arrivals.iter().map(|a| a.client).collect();
    ids.sort_unstable();
    ids
}

/// Spawn an honest uploader for round 0. Returns whether the upload was
/// acked; connect/handshake failures propagate as `Err`.
fn spawn_uploader(
    addr: &str,
    fx: &Fixture,
    id: u64,
    alpha: f64,
    opts: SessionOpts,
) -> JoinHandle<anyhow::Result<bool>> {
    let addr = addr.to_string();
    let ctx = fx.ctx.clone();
    let pk = fx.pk.clone();
    let mask = fx.mask.clone();
    let total = fx.total;
    std::thread::spawn(move || {
        let codec = SelectiveCodec::new(ctx.clone());
        let (mut sess, _) = ClientSession::connect(&addr, id, ctx.params.clone(), opts)?;
        let upd = encrypt_client_update(&codec, &pk, &mask, total, id);
        match sess.upload(0, alpha, &upd, None) {
            Ok(receipt) => Ok(receipt.acked),
            Err(_) => Ok(false),
        }
    })
}

fn join_uploader(h: JoinHandle<anyhow::Result<bool>>) -> anyhow::Result<bool> {
    h.join().map_err(|_| anyhow::anyhow!("uploader thread panicked"))?
}

fn mac_opts(root: &[u8; 32], id: u64) -> SessionOpts {
    SessionOpts {
        auth: Some(derive_client_key(root, id)),
        connect_retry: Duration::from_secs(5),
        io_timeout: Duration::from_secs(5),
        ..SessionOpts::default()
    }
}

fn collect_cfg(expected: usize, quorum: Option<usize>) -> IntakeConfig {
    IntakeConfig {
        round_id: 0,
        expected_uploads: expected,
        quorum,
        straggler_timeout: if quorum.is_some() {
            Duration::from_secs(1)
        } else {
            Duration::from_secs(5)
        },
        max_wait: Duration::from_secs(30),
        io_timeout: if quorum.is_some() {
            Duration::from_secs(2)
        } else {
            Duration::from_secs(5)
        },
    }
}

/// An attacker who knows a valid client id (but not its key) tries to
/// steal the slot mid-task. The handshake must reject it pre-slot, the
/// honest session must survive, and the round must seal bitwise clean.
fn forged_identity(fx: &Fixture) -> anyhow::Result<String> {
    let root = [0x42u8; 32];
    let mut hub =
        SessionHub::bind_with_auth("127.0.0.1:0", fx.ctx.params.clone(), 8, Some(root))?;
    let addr = hub.local_addr()?.to_string();
    let third = 1.0 / 3.0;
    let handles: Vec<_> = (0..3u64)
        .map(|id| spawn_uploader(&addr, fx, id, third, mac_opts(&root, id)))
        .collect();
    hub.wait_for_clients(3, Duration::from_secs(5))?;

    let auth0 = metrics::snapshot_auth_rejects();
    // key derived for a different id = a forged proof for the claimed one
    let forged = ClientSession::connect(
        &addr,
        1,
        fx.ctx.params.clone(),
        SessionOpts {
            auth: Some(derive_client_key(&root, 99)),
            connect_retry: Duration::from_millis(10),
            io_timeout: Duration::from_secs(2),
            connect_retries: 0,
            ..SessionOpts::default()
        },
    );
    anyhow::ensure!(forged.is_err(), "forged identity must not be welcomed");
    let auth_delta = metrics::snapshot_auth_rejects() - auth0;
    anyhow::ensure!(auth_delta > 0, "forgery must count an auth_reject");
    anyhow::ensure!(
        hub.connected() == [0, 1, 2],
        "honest slots must survive the forgery, got {:?}",
        hub.connected()
    );

    let outcome = hub.collect_round(
        &[(0, Some(third)), (1, Some(third)), (2, Some(third))],
        fx.shape,
        &collect_cfg(3, None),
    );
    for h in handles {
        anyhow::ensure!(join_uploader(h)?, "honest upload must be acked");
    }
    anyhow::ensure!(outcome.failed.is_empty(), "no honest upload may fail: {:?}", outcome.failed);
    let (agg, stats) = wire_agg(fx, outcome.arrivals)?;
    let (ref_agg, ref_stats) = reference_agg(fx, &[0, 1, 2], 3)?;
    anyhow::ensure!(updates_bitwise_eq(&agg, &ref_agg), "aggregate must match fault-free run");
    anyhow::ensure!(
        bits(&renormalized_global(fx, &agg, stats.alpha_mass))
            == bits(&renormalized_global(fx, &ref_agg, ref_stats.alpha_mass)),
        "decrypted global must be bitwise identical"
    );
    hub.shutdown();
    Ok(format!("auth_rejects +{auth_delta}, 3/3 honest uploads, aggregate bitwise clean"))
}

/// A wire adversary (modeled by the duplicate fault) replays every
/// post-handshake frame of one client. Replays are discarded, counted,
/// and the round still seals bitwise identical.
fn replayed_upload(fx: &Fixture) -> anyhow::Result<String> {
    let root = [0x37u8; 32];
    let mut hub =
        SessionHub::bind_with_auth("127.0.0.1:0", fx.ctx.params.clone(), 8, Some(root))?;
    let addr = hub.local_addr()?.to_string();
    let third = 1.0 / 3.0;
    let replay0 = metrics::snapshot_replay_rejects();
    let handles: Vec<_> = (0..3u64)
        .map(|id| {
            let mut opts = mac_opts(&root, id);
            if id == 1 {
                // duplicate every frame after HELLO + CHALLENGE_RESP: an
                // on-path replay of the authenticated upload stream
                opts.chaos = Some(ChaosConfig {
                    duplicate_per_mille: 1000,
                    immune_prefix: 2,
                    ..ChaosConfig::passthrough(0xD5)
                });
            }
            spawn_uploader(&addr, fx, id, third, opts)
        })
        .collect();
    hub.wait_for_clients(3, Duration::from_secs(5))?;
    let outcome = hub.collect_round(
        &[(0, Some(third)), (1, Some(third)), (2, Some(third))],
        fx.shape,
        &collect_cfg(3, None),
    );
    for h in handles {
        anyhow::ensure!(join_uploader(h)?, "upload must be acked despite replays");
    }
    anyhow::ensure!(outcome.failed.is_empty(), "replays must not fail the client");
    let replay_delta = metrics::snapshot_replay_rejects() - replay0;
    anyhow::ensure!(replay_delta > 0, "replayed frames must count replay_rejects");
    let (agg, stats) = wire_agg(fx, outcome.arrivals)?;
    let (ref_agg, ref_stats) = reference_agg(fx, &[0, 1, 2], 3)?;
    anyhow::ensure!(updates_bitwise_eq(&agg, &ref_agg), "replays must not perturb the aggregate");
    anyhow::ensure!(
        bits(&renormalized_global(fx, &agg, stats.alpha_mass))
            == bits(&renormalized_global(fx, &ref_agg, ref_stats.alpha_mass)),
        "decrypted global must be bitwise identical"
    );
    hub.shutdown();
    Ok(format!("replay_rejects +{replay_delta}, aggregate bitwise clean"))
}

/// Raw-socket adversaries attack the handshake itself: a double HELLO for
/// an honest id, and a garbage challenge proof for a fresh id. Neither may
/// ever see WELCOME; the honest client's slot and round stay intact.
fn duplicate_hello(fx: &Fixture) -> anyhow::Result<String> {
    let root = [0x6Bu8; 32];
    let mut hub =
        SessionHub::bind_with_auth("127.0.0.1:0", fx.ctx.params.clone(), 8, Some(root))?;
    let addr = hub.local_addr()?.to_string();
    let honest = spawn_uploader(&addr, fx, 0, 1.0, mac_opts(&root, 0));
    hub.wait_for_clients(1, Duration::from_secs(5))?;

    // never a WELCOME on this socket, whatever else the server says
    let drain_refuses_welcome = |stream: TcpStream| -> anyhow::Result<bool> {
        stream.set_read_timeout(Some(Duration::from_secs(2)))?;
        let mut rd = BufReader::new(stream);
        let mut buf = Vec::new();
        loop {
            match read_frame_into(&mut rd, CONTROL_ROUND, 1 << 16, &mut buf) {
                Ok((FrameKind::Welcome, _)) => return Ok(false),
                Ok(_) => continue, // e.g. the CHALLENGE
                Err(_) => return Ok(true), // server hung up on us
            }
        }
    };

    // adversary A: two HELLOs back-to-back, claiming the honest id
    let mut a = TcpStream::connect(&addr)?;
    a.set_nodelay(true).ok();
    let hello = encode_hello(0, CtWire::Dense);
    write_frame(&mut a, CONTROL_ROUND, FrameKind::Hello, 0, &hello)?;
    write_frame(&mut a, CONTROL_ROUND, FrameKind::Hello, 1, &hello)?;
    anyhow::ensure!(
        drain_refuses_welcome(a)?,
        "duplicate HELLO must never reach WELCOME"
    );

    // adversary B: fresh id, answers the challenge with a junk proof
    let auth0 = metrics::snapshot_auth_rejects();
    let mut b = TcpStream::connect(&addr)?;
    b.set_nodelay(true).ok();
    b.set_read_timeout(Some(Duration::from_secs(2)))?;
    write_frame(&mut b, CONTROL_ROUND, FrameKind::Hello, 0, &encode_hello(9, CtWire::Dense))?;
    let mut rd = BufReader::new(b.try_clone()?);
    let mut buf = Vec::new();
    let (kind, _) = read_frame_into(&mut rd, CONTROL_ROUND, 1 << 16, &mut buf)?;
    anyhow::ensure!(kind == FrameKind::Challenge, "mac hub must challenge, got {kind:?}");
    write_frame(
        &mut b,
        CONTROL_ROUND,
        FrameKind::ChallengeResp,
        0,
        &encode_challenge_resp(9, 0xDEAD_BEEF),
    )?;
    let refused = loop {
        match read_frame_into(&mut rd, CONTROL_ROUND, 1 << 16, &mut buf) {
            Ok((FrameKind::Welcome, _)) => break false,
            Ok(_) => continue,
            Err(_) => break true,
        }
    };
    anyhow::ensure!(refused, "junk challenge proof must never reach WELCOME");
    let auth_delta = metrics::snapshot_auth_rejects() - auth0;
    anyhow::ensure!(auth_delta > 0, "junk proof must count an auth_reject");
    anyhow::ensure!(hub.connected() == [0], "honest slot must survive the handshake attacks");

    let outcome = hub.collect_round(&[(0, Some(1.0))], fx.shape, &collect_cfg(1, None));
    anyhow::ensure!(join_uploader(honest)?, "honest upload must be acked");
    anyhow::ensure!(outcome.failed.is_empty(), "honest upload must not fail");
    let (agg, stats) = wire_agg(fx, outcome.arrivals)?;
    let (ref_agg, ref_stats) = reference_agg(fx, &[0], 1)?;
    anyhow::ensure!(updates_bitwise_eq(&agg, &ref_agg), "aggregate must match fault-free run");
    anyhow::ensure!(
        bits(&renormalized_global(fx, &agg, stats.alpha_mass))
            == bits(&renormalized_global(fx, &ref_agg, ref_stats.alpha_mass)),
        "decrypted global must be bitwise identical"
    );
    hub.shutdown();
    Ok(format!("both handshake adversaries refused, auth_rejects +{auth_delta}"))
}

/// An adversary (or a misconfigured client) announces the seeded
/// ciphertext wire on a task pinned to dense. The handshake must refuse it
/// before a slot is claimed — ciphertext framing is task-level, never
/// negotiated per client — and the honest round still seals bitwise clean.
fn wire_mode_confusion(fx: &Fixture) -> anyhow::Result<String> {
    let root = [0x5Eu8; 32];
    let mut hub =
        SessionHub::bind_with_auth("127.0.0.1:0", fx.ctx.params.clone(), 8, Some(root))?;
    let addr = hub.local_addr()?.to_string();
    let honest = spawn_uploader(&addr, fx, 0, 1.0, mac_opts(&root, 0));
    hub.wait_for_clients(1, Duration::from_secs(5))?;

    // raw socket announcing the seeded wire against the dense task
    let mut a = TcpStream::connect(&addr)?;
    a.set_nodelay(true).ok();
    a.set_read_timeout(Some(Duration::from_secs(2)))?;
    write_frame(&mut a, CONTROL_ROUND, FrameKind::Hello, 0, &encode_hello(5, CtWire::Seed))?;
    let mut rd = BufReader::new(a);
    let mut buf = Vec::new();
    let refused = loop {
        match read_frame_into(&mut rd, CONTROL_ROUND, 1 << 16, &mut buf) {
            Ok((FrameKind::Welcome, _)) => break false,
            Ok(_) => continue,
            Err(_) => break true,
        }
    };
    anyhow::ensure!(refused, "a seed-wire HELLO on a dense task must never reach WELCOME");

    // the full client stack refuses the same mismatch loudly at connect
    let mis = ClientSession::connect(
        &addr,
        6,
        fx.ctx.params.clone(),
        SessionOpts {
            ct_wire: CtWire::Seed,
            connect_retries: 0,
            ..mac_opts(&root, 6)
        },
    );
    anyhow::ensure!(mis.is_err(), "a seed-configured client must fail against a dense task");
    anyhow::ensure!(hub.connected() == [0], "honest slot must survive the mode confusion");

    let outcome = hub.collect_round(&[(0, Some(1.0))], fx.shape, &collect_cfg(1, None));
    anyhow::ensure!(join_uploader(honest)?, "honest upload must be acked");
    anyhow::ensure!(outcome.failed.is_empty(), "honest upload must not fail");
    let (agg, stats) = wire_agg(fx, outcome.arrivals)?;
    let (ref_agg, ref_stats) = reference_agg(fx, &[0], 1)?;
    anyhow::ensure!(updates_bitwise_eq(&agg, &ref_agg), "aggregate must match fault-free run");
    anyhow::ensure!(
        bits(&renormalized_global(fx, &agg, stats.alpha_mass))
            == bits(&renormalized_global(fx, &ref_agg, ref_stats.alpha_mass)),
        "decrypted global must be bitwise identical"
    );
    hub.shutdown();
    Ok("seed-wire HELLO refused pre-slot, honest round sealed bitwise clean".to_string())
}

/// Three of five clients vanish mid-upload. The round seals on the
/// surviving quorum with the dead clients accounted as failed, and the
/// survivors' aggregate matches the fault-free subset reference.
fn disconnect_storm(fx: &Fixture) -> anyhow::Result<String> {
    let root = [0x13u8; 32];
    let mut hub =
        SessionHub::bind_with_auth("127.0.0.1:0", fx.ctx.params.clone(), 16, Some(root))?;
    let addr = hub.local_addr()?.to_string();
    let fifth = 0.2f64;
    let chaos0 = metrics::snapshot_chaos_injected();
    let handles: Vec<_> = (0..5u64)
        .map(|id| {
            let mut opts = mac_opts(&root, id);
            if id >= 2 {
                // frames 1-3 are HELLO, CHALLENGE_RESP, BEGIN: sever on
                // the first ciphertext chunk of the upload
                opts.chaos = Some(ChaosConfig {
                    disconnect_at_frame: Some(4),
                    ..ChaosConfig::passthrough(0x111 + id)
                });
                opts.connect_retries = 0;
            }
            spawn_uploader(&addr, fx, id, fifth, opts)
        })
        .collect();
    hub.wait_for_clients(5, Duration::from_secs(5))?;
    let expected: Vec<(u64, Option<f64>)> = (0..5u64).map(|id| (id, Some(fifth))).collect();
    let outcome = hub.collect_round(&expected, fx.shape, &collect_cfg(5, Some(2)));
    let acked: Vec<bool> =
        handles.into_iter().map(join_uploader).collect::<anyhow::Result<_>>()?;
    anyhow::ensure!(acked[0] && acked[1], "surviving clients must be acked");
    anyhow::ensure!(
        !acked[2] && !acked[3] && !acked[4],
        "severed clients must see their upload fail"
    );
    anyhow::ensure!(
        sorted_ids(&outcome.arrivals) == [0, 1],
        "exactly the survivors must arrive, got {:?}",
        sorted_ids(&outcome.arrivals)
    );
    for id in 2..5u64 {
        anyhow::ensure!(
            outcome.failed.contains(&id),
            "client {id} must be accounted failed, got {:?}",
            outcome.failed
        );
    }
    let chaos_delta = metrics::snapshot_chaos_injected() - chaos0;
    anyhow::ensure!(chaos_delta > 0, "the storm must be visible in chaos_injected");
    let (agg, stats) = wire_agg(fx, outcome.arrivals)?;
    let (ref_agg, ref_stats) = reference_agg(fx, &[0, 1], 5)?;
    anyhow::ensure!(updates_bitwise_eq(&agg, &ref_agg), "survivor aggregate must match reference");
    anyhow::ensure!(
        bits(&renormalized_global(fx, &agg, stats.alpha_mass))
            == bits(&renormalized_global(fx, &ref_agg, ref_stats.alpha_mass)),
        "survivor global must be bitwise identical"
    );
    hub.shutdown();
    Ok(format!(
        "2/5 sealed, 3 failed on record, chaos_injected +{chaos_delta}, mass {:.3}",
        stats.alpha_mass
    ))
}

/// A malicious server aggregates only the clients it likes. It can bias
/// the model — but it cannot hide the weight deficit (`alpha_mass` rides
/// the authenticated preamble to every client identically), and it never
/// learns the updates it dropped: it only ever held ciphertexts.
fn cherry_picking_server(fx: &Fixture) -> anyhow::Result<String> {
    let root = [0x21u8; 32];
    let mut hub =
        SessionHub::bind_with_auth("127.0.0.1:0", fx.ctx.params.clone(), 8, Some(root))?;
    let addr = hub.local_addr()?.to_string();
    let third = 1.0 / 3.0;
    let shape = fx.shape;
    let handles: Vec<_> = (0..3u64)
        .map(|id| {
            let addr = addr.clone();
            let ctx = fx.ctx.clone();
            let pk = fx.pk.clone();
            let sk = fx.sk.clone();
            let mask = fx.mask.clone();
            let total = fx.total;
            let opts = mac_opts(&root, id);
            std::thread::spawn(move || -> anyhow::Result<(f64, Vec<u32>)> {
                let codec = SelectiveCodec::new(ctx.clone());
                let (mut sess, _) = ClientSession::connect(&addr, id, ctx.params.clone(), opts)?;
                let upd = encrypt_client_update(&codec, &pk, &mask, total, id);
                let receipt = sess.upload(0, third, &upd, None)?;
                anyhow::ensure!(receipt.acked, "upload must be acked");
                let dl = sess.recv_round(1, Some(shape))?;
                anyhow::ensure!(dl.down.has_agg && dl.down.fin, "expected the final aggregate");
                let agg = dl.agg.expect("has_agg downlink carries the aggregate");
                let mut g = codec.decrypt_update(&agg, &mask, &sk);
                if (dl.down.alpha_mass - 1.0).abs() > 1e-12 {
                    for v in g.iter_mut() {
                        *v = (*v as f64 / dl.down.alpha_mass) as f32;
                    }
                }
                Ok((dl.down.alpha_mass, bits(&g)))
            })
        })
        .collect();
    hub.wait_for_clients(3, Duration::from_secs(5))?;
    let outcome = hub.collect_round(
        &[(0, Some(third)), (1, Some(third)), (2, Some(third))],
        fx.shape,
        &collect_cfg(3, None),
    );
    anyhow::ensure!(outcome.failed.is_empty(), "all three uploads must land");
    // the cherry-pick: silently drop client 2's upload before aggregation
    let picked: Vec<Arrival> =
        outcome.arrivals.into_iter().filter(|a| a.client != 2).collect();
    let (agg, stats) = wire_agg(fx, picked)?;
    let plans: Vec<(u64, DownBegin)> = (0..3u64)
        .map(|id| {
            (
                id,
                DownBegin {
                    alpha: 0.0,
                    alpha_mass: stats.alpha_mass,
                    n_cts: shape.n_cts,
                    n_plain: shape.n_plain,
                    total: shape.total,
                    participate: false,
                    has_agg: true,
                    fin: true,
                },
            )
        })
        .collect();
    let out = hub.broadcast_round(1, &plans, Some(&agg));
    anyhow::ensure!(out.failed.is_empty(), "downlink must reach all clients: {:?}", out.failed);
    let mut views: Vec<(f64, Vec<u32>)> = Vec::new();
    for h in handles {
        views.push(h.join().map_err(|_| anyhow::anyhow!("client thread panicked"))??);
    }

    let (ref_agg, ref_stats) = reference_agg(fx, &[0, 1], 3)?;
    let subset_bits = bits(&renormalized_global(fx, &ref_agg, ref_stats.alpha_mass));
    let (full_agg, full_stats) = reference_agg(fx, &[0, 1, 2], 3)?;
    let full_bits = bits(&renormalized_global(fx, &full_agg, full_stats.alpha_mass));
    for (mass, g) in &views {
        // the deficit is visible: every client sees Σα = 2/3, not 1
        anyhow::ensure!((mass - 2.0 / 3.0).abs() < 1e-9, "deficit must be visible, saw {mass}");
        anyhow::ensure!(*g == subset_bits, "every client must see the same (biased) model");
    }
    anyhow::ensure!(subset_bits != full_bits, "the cherry-pick must actually change the model");
    hub.shutdown();
    Ok(format!(
        "bias visible to all 3 clients as alpha_mass {:.4} != 1.0, views bitwise consistent",
        views[0].0
    ))
}

/// A five-client round under a mixed seeded chaos schedule: one uplink
/// drops everything, one corrupts every frame (each rejected by the MAC,
/// never a panic), one disconnects, two stay clean. The round seals on
/// the clean pair with everyone else on the failed record.
fn chaos_round(fx: &Fixture) -> anyhow::Result<String> {
    let root = [0x77u8; 32];
    let mut hub =
        SessionHub::bind_with_auth("127.0.0.1:0", fx.ctx.params.clone(), 16, Some(root))?;
    let addr = hub.local_addr()?.to_string();
    let fifth = 0.2f64;
    let chaos0 = metrics::snapshot_chaos_injected();
    let auth0 = metrics::snapshot_auth_rejects();
    let handles: Vec<_> = (0..5u64)
        .map(|id| {
            let mut opts = mac_opts(&root, id);
            // frames 1-3 (HELLO, CHALLENGE_RESP, BEGIN) pass untouched
            match id {
                0 => {
                    opts.chaos = Some(ChaosConfig {
                        drop_per_mille: 1000,
                        immune_prefix: 3,
                        ..ChaosConfig::passthrough(0xA0)
                    });
                    opts.round_wait = Duration::from_secs(3);
                }
                1 => {
                    opts.chaos = Some(ChaosConfig {
                        corrupt_per_mille: 1000,
                        immune_prefix: 3,
                        ..ChaosConfig::passthrough(0xA1)
                    });
                    opts.round_wait = Duration::from_secs(3);
                }
                2 => {
                    opts.chaos = Some(ChaosConfig {
                        disconnect_at_frame: Some(5),
                        ..ChaosConfig::passthrough(0xA2)
                    });
                }
                _ => {}
            }
            if id < 3 {
                opts.connect_retries = 0;
            }
            spawn_uploader(&addr, fx, id, fifth, opts)
        })
        .collect();
    hub.wait_for_clients(5, Duration::from_secs(5))?;
    let expected: Vec<(u64, Option<f64>)> = (0..5u64).map(|id| (id, Some(fifth))).collect();
    let outcome = hub.collect_round(&expected, fx.shape, &collect_cfg(5, Some(2)));
    let acked: Vec<bool> =
        handles.into_iter().map(join_uploader).collect::<anyhow::Result<_>>()?;
    anyhow::ensure!(acked[3] && acked[4], "clean clients must be acked");
    anyhow::ensure!(
        !acked[0] && !acked[1] && !acked[2],
        "chaos-hit clients must see their upload fail"
    );
    anyhow::ensure!(
        sorted_ids(&outcome.arrivals) == [3, 4],
        "exactly the clean pair must arrive, got {:?}",
        sorted_ids(&outcome.arrivals)
    );
    for id in 0..3u64 {
        anyhow::ensure!(
            outcome.failed.contains(&id),
            "client {id} must be accounted failed, got {:?}",
            outcome.failed
        );
    }
    let chaos_delta = metrics::snapshot_chaos_injected() - chaos0;
    let auth_delta = metrics::snapshot_auth_rejects() - auth0;
    anyhow::ensure!(chaos_delta > 0, "the schedule must count chaos_injected");
    anyhow::ensure!(auth_delta > 0, "corrupted frames must count auth_rejects");
    let (agg, stats) = wire_agg(fx, outcome.arrivals)?;
    let (ref_agg, ref_stats) = reference_agg(fx, &[3, 4], 5)?;
    anyhow::ensure!(updates_bitwise_eq(&agg, &ref_agg), "clean-pair aggregate must match");
    anyhow::ensure!(
        bits(&renormalized_global(fx, &agg, stats.alpha_mass))
            == bits(&renormalized_global(fx, &ref_agg, ref_stats.alpha_mass)),
        "clean-pair global must be bitwise identical"
    );
    hub.shutdown();
    Ok(format!(
        "2/5 sealed, chaos_injected +{chaos_delta}, auth_rejects +{auth_delta}, mass {:.3}",
        stats.alpha_mass
    ))
}

/// Run every scenario against a fresh fixture, converting failures (and
/// panics) into reports instead of aborting the sweep.
pub fn run_all() -> Vec<ScenarioReport> {
    type Scenario = fn(&Fixture) -> anyhow::Result<String>;
    let scenarios: [(&'static str, Scenario); 7] = [
        ("forged_identity", forged_identity),
        ("replayed_upload", replayed_upload),
        ("duplicate_hello", duplicate_hello),
        ("wire_mode_confusion", wire_mode_confusion),
        ("disconnect_storm", disconnect_storm),
        ("cherry_picking_server", cherry_picking_server),
        ("chaos_round", chaos_round),
    ];
    let fx = fixture();
    scenarios
        .iter()
        .map(|&(name, f)| {
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&fx))) {
                Ok(Ok(detail)) => ScenarioReport { name, passed: true, detail },
                Ok(Err(e)) => ScenarioReport { name, passed: false, detail: format!("{e:#}") },
                Err(_) => ScenarioReport {
                    name,
                    passed: false,
                    detail: "scenario panicked".to_string(),
                },
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forged_identity_is_rejected_and_the_round_stays_clean() {
        forged_identity(&fixture()).unwrap();
    }

    #[test]
    fn replayed_uploads_are_rejected_without_perturbing_the_aggregate() {
        replayed_upload(&fixture()).unwrap();
    }

    #[test]
    fn handshake_adversaries_never_reach_welcome() {
        duplicate_hello(&fixture()).unwrap();
    }

    #[test]
    fn cherry_picking_server_cannot_hide_the_deficit() {
        cherry_picking_server(&fixture()).unwrap();
    }

    #[test]
    fn wire_mode_confusion_is_refused_pre_slot() {
        wire_mode_confusion(&fixture()).unwrap();
    }
}
