//! Image-similarity metrics for attack evaluation (from-scratch substitutes
//! for the paper's sewar MSSSIM/VIF/UQI — monotone proxies for recovery
//! quality; see DESIGN.md §3).

/// Mean squared error.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// Peak signal-to-noise ratio with the data range estimated from `a`.
pub fn psnr(a: &[f32], b: &[f32]) -> f64 {
    let range = a.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
        - a.iter().cloned().fold(f32::INFINITY, f32::min);
    let range = range.max(1e-6) as f64;
    let m = mse(a, b);
    if m == 0.0 {
        f64::INFINITY
    } else {
        10.0 * (range * range / m).log10()
    }
}

/// Global SSIM (single-window variant over the whole image) per channel,
/// averaged; inputs are CHW flat.
pub fn ssim(a: &[f32], b: &[f32], channels: usize) -> f64 {
    assert_eq!(a.len(), b.len());
    assert!(channels > 0 && a.len() % channels == 0);
    let per = a.len() / channels;
    // dynamic range from the reference image
    let range = (a.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
        - a.iter().cloned().fold(f32::INFINITY, f32::min))
    .max(1e-6) as f64;
    let c1 = (0.01 * range).powi(2);
    let c2 = (0.03 * range).powi(2);
    let mut acc = 0.0;
    for c in 0..channels {
        let xa = &a[c * per..(c + 1) * per];
        let xb = &b[c * per..(c + 1) * per];
        let ma = xa.iter().map(|&v| v as f64).sum::<f64>() / per as f64;
        let mb = xb.iter().map(|&v| v as f64).sum::<f64>() / per as f64;
        let mut va = 0.0;
        let mut vb = 0.0;
        let mut cov = 0.0;
        for i in 0..per {
            let da = xa[i] as f64 - ma;
            let db = xb[i] as f64 - mb;
            va += da * da;
            vb += db * db;
            cov += da * db;
        }
        va /= per as f64 - 1.0;
        vb /= per as f64 - 1.0;
        cov /= per as f64 - 1.0;
        acc += ((2.0 * ma * mb + c1) * (2.0 * cov + c2))
            / ((ma * ma + mb * mb + c1) * (va + vb + c2));
    }
    acc / channels as f64
}

/// Bundle of all metrics for one (reference, recovered) pair.
#[derive(Debug, Clone, Copy)]
pub struct Similarity {
    pub mse: f64,
    pub psnr: f64,
    pub ssim: f64,
}

pub fn similarity(reference: &[f32], recovered: &[f32], channels: usize) -> Similarity {
    Similarity {
        mse: mse(reference, recovered),
        psnr: psnr(reference, recovered),
        ssim: ssim(reference, recovered, channels),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::prng::ChaChaRng;

    fn image(seed: u64) -> Vec<f32> {
        let mut rng = ChaChaRng::from_seed(seed, 0);
        (0..784)
            .map(|i| ((i as f32) * 0.05).sin() + 0.2 * rng.normal_f64() as f32)
            .collect()
    }

    #[test]
    fn identical_images_are_perfect() {
        let a = image(1);
        assert_eq!(mse(&a, &a), 0.0);
        assert!(psnr(&a, &a).is_infinite());
        assert!((ssim(&a, &a, 1) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn metrics_order_by_degradation() {
        let a = image(1);
        let mut rng = ChaChaRng::from_seed(9, 9);
        let slightly: Vec<f32> = a.iter().map(|&v| v + 0.05 * rng.normal_f64() as f32).collect();
        let heavily: Vec<f32> = a.iter().map(|&v| v + 1.5 * rng.normal_f64() as f32).collect();
        assert!(mse(&a, &slightly) < mse(&a, &heavily));
        assert!(psnr(&a, &slightly) > psnr(&a, &heavily));
        assert!(ssim(&a, &slightly, 1) > ssim(&a, &heavily, 1));
        // unrelated pure-noise image: ssim well below the related ones
        let mut nrng = ChaChaRng::from_seed(123, 4);
        let noise: Vec<f32> = (0..784).map(|_| nrng.normal_f64() as f32).collect();
        assert!(ssim(&a, &noise, 1) < ssim(&a, &heavily, 1) + 0.2);
        assert!(ssim(&a, &noise, 1) < 0.7);
    }

    #[test]
    fn ssim_bounded() {
        let a = image(2);
        let b = image(3);
        let s = ssim(&a, &b, 1);
        assert!((-1.0..=1.0).contains(&s));
    }
}
