//! Language-model inversion analog (Fig. 10).
//!
//! Decepticons-style attacks recover which tokens appeared in a client's
//! batch from the embedding-layer gradient: an embedding row has nonzero
//! gradient iff its token occurred. Selective Parameter Encryption hides
//! the most sensitive rows, driving the recovery rate down. This module
//! measures exactly that token-recovery rate from a (masked) flat gradient.

use crate::he_agg::EncryptionMask;

/// Token recovery from the embedding-gradient rows.
///
/// * `grad` — flat gradient; the first `vocab · d_model` entries are the
///   embedding table (models.py spec order).
/// * `mask` — encryption mask; protected coordinates are invisible (zeroed).
/// Returns the set of tokens the attacker infers as present.
pub fn recover_tokens(
    grad: &[f32],
    mask: &EncryptionMask,
    vocab: usize,
    d_model: usize,
    threshold: f32,
) -> Vec<usize> {
    assert!(grad.len() >= vocab * d_model);
    let dense = mask.to_dense();
    let mut tokens = Vec::new();
    for t in 0..vocab {
        let row = &grad[t * d_model..(t + 1) * d_model];
        let vis = &dense[t * d_model..(t + 1) * d_model];
        let norm: f32 = row
            .iter()
            .zip(vis.iter())
            .filter(|(_, &enc)| !enc)
            .map(|(&g, _)| g * g)
            .sum::<f32>()
            .sqrt();
        if norm > threshold {
            tokens.push(t);
        }
    }
    tokens
}

/// Attack quality: fraction of actually-present tokens recovered, and the
/// false-positive count.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveryScore {
    pub recall: f64,
    pub false_positives: usize,
}

pub fn score_recovery(recovered: &[usize], actual: &[i32]) -> RecoveryScore {
    let actual_set: std::collections::BTreeSet<usize> =
        actual.iter().map(|&t| t as usize).collect();
    let recovered_set: std::collections::BTreeSet<usize> = recovered.iter().copied().collect();
    let hit = recovered_set.intersection(&actual_set).count();
    RecoveryScore {
        recall: if actual_set.is_empty() {
            0.0
        } else {
            hit as f64 / actual_set.len() as f64
        },
        false_positives: recovered_set.difference(&actual_set).count(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const VOCAB: usize = 16;
    const D: usize = 4;

    fn grad_with_tokens(tokens: &[usize]) -> Vec<f32> {
        let mut g = vec![0.0f32; VOCAB * D + 100];
        for &t in tokens {
            for j in 0..D {
                g[t * D + j] = 0.5 + j as f32 * 0.1;
            }
        }
        g
    }

    #[test]
    fn unprotected_gradient_leaks_all_tokens() {
        let g = grad_with_tokens(&[2, 7, 11]);
        let mask = EncryptionMask::empty(g.len());
        let rec = recover_tokens(&g, &mask, VOCAB, D, 1e-3);
        assert_eq!(rec, vec![2, 7, 11]);
        let s = score_recovery(&rec, &[2, 7, 11]);
        assert_eq!(s.recall, 1.0);
        assert_eq!(s.false_positives, 0);
    }

    #[test]
    fn masking_embedding_rows_blocks_recovery() {
        let g = grad_with_tokens(&[2, 7, 11]);
        // protect the embedding region entirely (one run)
        let mask = EncryptionMask::from_runs(
            g.len(),
            vec![crate::he_agg::mask::Run { lo: 0, hi: VOCAB * D }],
        );
        let rec = recover_tokens(&g, &mask, VOCAB, D, 1e-3);
        assert!(rec.is_empty());
        assert_eq!(score_recovery(&rec, &[2, 7, 11]).recall, 0.0);
    }

    #[test]
    fn partial_masking_partially_protects() {
        let g = grad_with_tokens(&[2, 7, 11]);
        // protect only token 7's row
        let mask = EncryptionMask::from_runs(
            g.len(),
            vec![crate::he_agg::mask::Run { lo: 7 * D, hi: 8 * D }],
        );
        let rec = recover_tokens(&g, &mask, VOCAB, D, 1e-3);
        assert_eq!(rec, vec![2, 11]);
        let s = score_recovery(&rec, &[2, 7, 11]);
        assert!((s.recall - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn sensitivity_guided_mask_beats_random_at_same_budget() {
        // Rows for present tokens are exactly the high-gradient (and thus
        // high-sensitivity) coordinates, so a sensitivity-ranked budget of
        // 3·D coordinates hides all tokens; a random budget of the same
        // size almost surely does not — the Remark 3.14 intuition.
        let g = grad_with_tokens(&[2, 7, 11]);
        let sens: Vec<f32> = g.iter().map(|&x| x.abs()).collect();
        let k = 3 * D;
        let p = k as f64 / g.len() as f64;
        let smart = EncryptionMask::top_p(&sens, p);
        let rec_smart = recover_tokens(&g, &smart, VOCAB, D, 1e-3);
        assert!(rec_smart.is_empty(), "smart mask leaks {rec_smart:?}");
        let mut rng = crate::crypto::prng::ChaChaRng::from_seed(3, 0);
        let rand = EncryptionMask::random(g.len(), p, &mut rng);
        let rec_rand = recover_tokens(&g, &rand, VOCAB, D, 1e-3);
        assert!(!rec_rand.is_empty(), "random mask unexpectedly perfect");
    }
}
