//! Small self-contained utility substrates.
//!
//! The offline build environment only ships the `xla` crate's dependency
//! closure, so the conveniences a production framework would pull from
//! crates.io (argument parsing, JSON, logging, stats) are implemented here
//! from scratch.

pub mod cli;
pub mod json;
pub mod logging;
pub mod stats;
pub mod table;

/// Write a file atomically: temp sibling + rename, so another process that
/// polls for the path's *existence* (the serve/join task-key and addr-file
/// hand-off) can never observe a created-but-partially-written file.
pub fn write_file_atomic(path: &std::path::Path, bytes: &[u8]) -> std::io::Result<()> {
    let name = path
        .file_name()
        .and_then(|s| s.to_str())
        .unwrap_or("file");
    let tmp = path.with_file_name(format!(".{name}.tmp-{}", std::process::id()));
    std::fs::write(&tmp, bytes)?;
    match std::fs::rename(&tmp, path) {
        Ok(()) => Ok(()),
        Err(e) => {
            std::fs::remove_file(&tmp).ok();
            Err(e)
        }
    }
}

/// Format a byte count as a human-readable string (KiB/MiB/GiB), matching the
/// unit style used in the paper's tables.
pub fn human_bytes(bytes: u64) -> String {
    const KB: f64 = 1024.0;
    let b = bytes as f64;
    if b >= KB * KB * KB {
        format!("{:.2} GB", b / (KB * KB * KB))
    } else if b >= KB * KB {
        format!("{:.2} MB", b / (KB * KB))
    } else if b >= KB {
        format!("{:.2} KB", b / KB)
    } else {
        format!("{bytes} B")
    }
}

/// Format seconds with adaptive precision.
pub fn human_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.1} s")
    } else if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KB");
        assert_eq!(human_bytes(5 * 1024 * 1024), "5.00 MB");
        assert_eq!(human_bytes(3 * 1024 * 1024 * 1024), "3.00 GB");
    }

    #[test]
    fn secs_units() {
        assert_eq!(human_secs(123.4), "123.4 s");
        assert_eq!(human_secs(1.5), "1.500 s");
        assert_eq!(human_secs(0.0025), "2.500 ms");
        assert_eq!(human_secs(2.5e-6), "2.500 us");
    }
}
