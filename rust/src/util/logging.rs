//! Tiny leveled logger writing to stderr (the `log` facade plus a consumer
//! would be overkill for a single binary; this keeps output deterministic).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

/// Log verbosity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    /// Parse a `--log-level` value.
    pub fn parse(s: &str) -> anyhow::Result<Level> {
        Ok(match s {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            other => anyhow::bail!(
                "unknown log level {other:?} (expected error|warn|info|debug)"
            ),
        })
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

/// Set the global verbosity.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current verbosity.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

/// Emit a log line if `lvl` is enabled.
pub fn log(lvl: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if lvl > level() {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match lvl {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    eprintln!("[{t:9.3}s {tag} {target}] {msg}");
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target, format_args!($($arg)*))
    };
}
#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target, format_args!($($arg)*))
    };
}
#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target, format_args!($($arg)*))
    };
}
#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_four_levels_only() {
        assert_eq!(Level::parse("error").unwrap(), Level::Error);
        assert_eq!(Level::parse("warn").unwrap(), Level::Warn);
        assert_eq!(Level::parse("info").unwrap(), Level::Info);
        assert_eq!(Level::parse("debug").unwrap(), Level::Debug);
        assert!(Level::parse("trace").is_err());
    }

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Debug);
        set_level(Level::Warn);
        assert_eq!(level(), Level::Warn);
        set_level(Level::Info);
    }
}
