//! Small statistics helpers used by benchmarks and metrics reports.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation (p in [0,100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Minimum (0 for empty).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min).min(f64::INFINITY)
}

/// Summary statistics of a sample.
#[derive(Debug, Clone, Copy, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        Summary {
            n: xs.len(),
            mean: mean(xs),
            std: std_dev(xs),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            p50: percentile(xs, 50.0),
            p95: percentile(xs, 95.0),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert!((mean(&xs) - 3.0).abs() < 1e-12);
        assert!((median(&xs) - 3.0).abs() < 1e-12);
        assert!((std_dev(&xs) - (2.0f64).sqrt()).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 5.0).abs() < 1e-12);
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn summary() {
        let s = Summary::of(&[2.0, 4.0]);
        assert_eq!(s.n, 2);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.min - 2.0).abs() < 1e-12);
        assert!((s.max - 4.0).abs() < 1e-12);
        let empty = Summary::of(&[]);
        assert_eq!(empty.n, 0);
    }
}
