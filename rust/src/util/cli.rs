//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Subcommands are handled by the caller peeling off the first
//! positional argument.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// `--key value` / `--key=value` options in order of appearance.
    opts: BTreeMap<String, String>,
    /// Bare `--flag` options.
    flags: Vec<String>,
    /// Positional arguments.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (excluding argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Self {
        let mut args = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(item) = iter.next() {
            if let Some(stripped) = item.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.opts.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else {
                args.positional.push(item);
            }
        }
        args
    }

    /// Parse the process arguments.
    pub fn parse() -> Self {
        Self::parse_from(std::env::args().skip(1))
    }

    /// Get a string option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// Get a string option with a default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Get a parsed numeric/typed option with a default.
    pub fn get_parsed_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key)
            .and_then(|v| v.parse::<T>().ok())
            .unwrap_or(default)
    }

    /// Get an *optional* parsed option, erroring on malformed values instead
    /// of silently falling back (for options like `--quorum` where "unset"
    /// and "invalid" must not be conflated).
    pub fn parsed<T: std::str::FromStr>(&self, key: &str) -> anyhow::Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("invalid --{key} '{v}': {e}")),
        }
    }

    /// Whether a bare `--flag` was passed.
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// First positional argument (the subcommand) and the rest.
    pub fn subcommand(&self) -> (Option<&str>, &[String]) {
        match self.positional.split_first() {
            Some((first, rest)) => (Some(first.as_str()), rest),
            None => (None, &[]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn key_value_styles() {
        // NOTE: a bare `--flag` must be last or followed by another
        // `--option`, otherwise the next token is consumed as its value.
        let a = parse("run pos1 --clients 8 --ratio=0.1 --verbose");
        assert_eq!(a.get("clients"), Some("8"));
        assert_eq!(a.get_parsed_or::<usize>("clients", 0), 8);
        assert_eq!(a.get_parsed_or::<f64>("ratio", 0.0), 0.1);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["run", "pos1"]);
        let (sub, rest) = a.subcommand();
        assert_eq!(sub, Some("run"));
        assert_eq!(rest, &["pos1".to_string()]);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_or("model", "lenet"), "lenet");
        assert_eq!(a.get_parsed_or::<u64>("rounds", 10), 10);
        assert!(!a.flag("verbose"));
        assert_eq!(a.subcommand().0, None);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("--dry-run");
        assert!(a.flag("dry-run"));
    }

    #[test]
    fn parsed_distinguishes_unset_from_invalid() {
        let a = parse("run --quorum 12");
        assert_eq!(a.parsed::<usize>("quorum").unwrap(), Some(12));
        assert_eq!(a.parsed::<usize>("population").unwrap(), None);
        let b = parse("run --quorum twelve");
        assert!(b.parsed::<usize>("quorum").is_err());
    }
}
