//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Covers the subset used by the framework: objects, arrays, strings,
//! numbers, booleans and null; no exotic escapes beyond `\uXXXX`.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|n| n as u64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let mut p = Parser {
            chars: text.chars().collect(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.chars.len() {
            anyhow::bail!("trailing characters at offset {}", p.pos);
        }
        Ok(v)
    }

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser {
    chars: Vec<char>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }
    fn next(&mut self) -> Option<char> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(c) if c.is_whitespace()) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: char) -> anyhow::Result<()> {
        match self.next() {
            Some(got) if got == c => Ok(()),
            got => anyhow::bail!("expected '{c}' at {}, got {:?}", self.pos, got),
        }
    }
    fn literal(&mut self, lit: &str, v: Json) -> anyhow::Result<Json> {
        for c in lit.chars() {
            self.expect(c)?;
        }
        Ok(v)
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some('{') => self.object(),
            Some('[') => self.array(),
            Some('"') => Ok(Json::Str(self.string()?)),
            Some('t') => self.literal("true", Json::Bool(true)),
            Some('f') => self.literal("false", Json::Bool(false)),
            Some('n') => self.literal("null", Json::Null),
            Some(c) if c == '-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at {}", other, self.pos),
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect('{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some('}') {
            self.next();
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.next() {
                Some(',') => continue,
                Some('}') => break,
                got => anyhow::bail!("expected ',' or '}}', got {:?}", got),
            }
        }
        Ok(Json::Obj(map))
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect('[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(']') {
            self.next();
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.next() {
                Some(',') => continue,
                Some(']') => break,
                got => anyhow::bail!("expected ',' or ']', got {:?}", got),
            }
        }
        Ok(Json::Arr(items))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect('"')?;
        let mut out = String::new();
        loop {
            match self.next() {
                Some('"') => break,
                Some('\\') => match self.next() {
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    Some('/') => out.push('/'),
                    Some('b') => out.push('\u{0008}'),
                    Some('f') => out.push('\u{000C}'),
                    Some('n') => out.push('\n'),
                    Some('r') => out.push('\r'),
                    Some('t') => out.push('\t'),
                    Some('u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.next().ok_or_else(|| anyhow::anyhow!("eof in \\u"))?;
                            code = code * 16
                                + c.to_digit(16)
                                    .ok_or_else(|| anyhow::anyhow!("bad hex in \\u"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    got => anyhow::bail!("bad escape {:?}", got),
                },
                Some(c) => out.push(c),
                None => anyhow::bail!("unterminated string"),
            }
        }
        Ok(out)
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        while matches!(self.peek(), Some(c) if c == '-' || c == '+' || c == '.'
            || c == 'e' || c == 'E' || c.is_ascii_digit())
        {
            self.pos += 1;
        }
        let s: String = self.chars[start..self.pos].iter().collect();
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\n", "d": true}, "e": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(
            v.get("b").unwrap().get("c").unwrap().as_str(),
            Some("hi\n")
        );
        assert_eq!(v.get("e"), Some(&Json::Null));
        // serialize and reparse
        let again = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn builders() {
        let v = Json::obj(vec![("n", 3u64.into()), ("xs", vec![1.0f64, 2.0].into())]);
        assert_eq!(v.get("n").unwrap().as_u64(), Some(3));
        assert_eq!(v.to_string(), r#"{"n":3,"xs":[1,2]}"#);
    }
}
