//! ASCII table printer used by the benchmark harnesses to emit rows in the
//! same layout as the paper's tables.

/// A simple left-padded column table.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width must match header"
        );
        self.rows.push(cells);
    }

    /// Render to a string (also suitable for EXPERIMENTS.md as markdown).
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("### {}\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for i in 0..ncol {
                line.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}-|", "-".repeat(w + 1)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_markdown() {
        let mut t = Table::new("Demo", &["Model", "Time (s)"]);
        t.row(vec!["LeNet".into(), "0.619".into()]);
        t.row(vec!["ResNet-50".into(), "46.672".into()]);
        let s = t.render();
        assert!(s.contains("### Demo"));
        assert!(s.contains("| LeNet"));
        assert!(s.contains("| ResNet-50 | 46.672"));
        // markdown separator present
        assert!(s.lines().nth(2).unwrap().starts_with("|-"));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
