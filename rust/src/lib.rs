//! # fedml_he — FedML-HE reproduction
//!
//! A from-scratch reproduction of *FedML-HE: An Efficient
//! Homomorphic-Encryption-Based Privacy-Preserving Federated Learning System*
//! (Jin et al., 2023) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the federated-learning coordinator: server round
//!   manager, client workers, key authority, threshold key agreement,
//!   encryption-mask agreement, dropout handling, bandwidth simulation,
//!   metrics, and a from-scratch RNS-CKKS crypto substrate ([`ckks`]).
//! * **L2 (`python/compile/model.py`)** — JAX compute graphs (train step,
//!   evaluation, parameter sensitivity, gradient-inversion attack step and the
//!   HE aggregation graph) AOT-lowered to HLO text at build time.
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels for the aggregation
//!   hot path (modular weighted sum over RNS ciphertext limbs, plaintext
//!   weighted sum), lowered into the same HLO artifacts.
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! AOT artifacts through the PJRT CPU client (`xla` crate) and the rest of the
//! system is pure Rust.
//!
//! The paper's headline contribution, **Selective Parameter Encryption**
//! (encrypt only the top-`p` most privacy-sensitive parameters), lives in
//! [`he_agg`]; the privacy-budget analysis of §3 lives in [`privacy`].

pub mod agg_engine;
pub mod attacks;
pub mod baselines;
pub mod bench_support;
pub mod ckks;
pub mod coordinator;
pub mod crypto;
pub mod fl;
pub mod he_agg;
pub mod netsim;
pub mod privacy;
pub mod runtime;
pub mod transport;
pub mod util;

/// Crate-wide result type (thin alias over `anyhow`).
pub type Result<T> = anyhow::Result<T>;

/// CLI dispatch for the `fedml-he` binary.
pub fn dispatch(args: util::cli::Args) -> Result<()> {
    if args.flag("verbose") {
        util::logging::set_level(util::logging::Level::Debug);
    }
    let artifacts = args.get_or("artifacts", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    let (sub, _rest) = args.subcommand();
    match sub {
        Some("run") => {
            let rt = runtime::Runtime::new(&artifacts)?;
            let cfg = coordinator::FlConfig::from_args(&args)?;
            let server = coordinator::FlServer::new(&rt, cfg)?;
            let (report, _global) = server.run()?;
            println!("{}", report.to_json());
            Ok(())
        }
        Some("params") => {
            let ctx = ckks::CkksContext::new(
                args.get_parsed_or("n", 8192),
                args.get_parsed_or("limbs", 4),
                args.get_parsed_or("scaling-bits", 52),
            )?;
            println!(
                "{}",
                util::json::Json::obj(vec![
                    ("n", ctx.params.n.into()),
                    ("batch", ctx.batch().into()),
                    ("moduli", ctx.params.moduli.clone().into()),
                    ("scaling_bits", (ctx.params.scaling_bits as u64).into()),
                    ("log2_q", ctx.params.log2_q().into()),
                    (
                        "ciphertext_bytes",
                        ctx.params.ciphertext_bytes().into()
                    ),
                ])
            );
            Ok(())
        }
        Some("privacy-map") => {
            let rt = runtime::Runtime::new(&artifacts)?;
            let model = args.get_or("model", "lenet");
            let rtm = rt
                .manifest
                .models
                .get(&model)
                .ok_or_else(|| anyhow::anyhow!("model '{model}' has no artifacts"))?
                .clone();
            let mut trainer = fl::LocalTrainer::new(&rt, &model)?;
            let params = rt.manifest.load_init_params(&model)?;
            let data = if model == "tinybert" {
                fl::Workload::Token(fl::data::synthetic_tokens(
                    0,
                    64,
                    rtm.seq_len.unwrap_or(16),
                    rtm.vocab.unwrap_or(128),
                    args.get_parsed_or("seed", 0),
                ))
            } else {
                fl::Workload::Image(fl::data::synthetic_images(
                    0,
                    64,
                    (1, 28, 28),
                    rtm.num_classes,
                    0.5,
                    args.get_parsed_or("seed", 0),
                ))
            };
            let s = trainer.sensitivity(&params, &data)?;
            let p: f64 = args.get_parsed_or("ratio", 0.1);
            let mask = he_agg::EncryptionMask::top_p(&s, p);
            let mut sorted = s.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let total: f64 = sorted.iter().map(|&v| v as f64).sum();
            let top: f64 = sorted[..mask.encrypted_count().max(1)]
                .iter()
                .map(|&v| v as f64)
                .sum();
            println!(
                "{}",
                util::json::Json::obj(vec![
                    ("model", model.into()),
                    ("params", s.len().into()),
                    ("ratio", p.into()),
                    ("encrypted", mask.encrypted_count().into()),
                    ("sensitivity_mass_captured", (top / total).into()),
                    ("max_sensitivity", (sorted[0] as f64).into()),
                    (
                        "median_sensitivity",
                        (sorted[sorted.len() / 2] as f64).into()
                    ),
                ])
            );
            Ok(())
        }
        Some("bench") => {
            eprintln!("benchmarks are cargo bench targets; run e.g.:");
            eprintln!("  cargo bench --bench table4_models");
            eprintln!("  cargo bench --bench perf_hotpath   # incl. sequential-vs-pipeline shards");
            eprintln!("see DESIGN.md §5 for the complete table/figure → bench mapping");
            Ok(())
        }
        Some(other) => anyhow::bail!(
            "unknown subcommand '{other}' (expected: run | params | privacy-map | bench)"
        ),
        None => {
            eprintln!("fedml-he — FedML-HE reproduction (Rust + JAX + Pallas via PJRT)");
            eprintln!();
            eprintln!("usage: fedml-he <subcommand> [--options]");
            eprintln!();
            eprintln!("subcommands:");
            eprintln!("  run           run a federated task (--model --clients --rounds --ratio");
            eprintln!("                --selection topp|random|full|none --mask-granularity param|layer");
            eprintln!("                --backend xla|native");
            eprintln!("                --keys single|threshold --bandwidth ib|sar|mar|aws200");
            eprintln!("                --dropout P --dp-scale B");
            eprintln!("                --engine sequential|pipeline --shards S --quorum K");
            eprintln!("                --straggler-timeout SECS --population N");
            eprintln!("                --transport sim|tcp --listen ADDR --connect ADDR");
            eprintln!("                --intake-max-wait SECS ...)");
            eprintln!("  params        print the CKKS context (--n --limbs --scaling-bits)");
            eprintln!("  privacy-map   compute a model's sensitivity map summary (--model --ratio)");
            eprintln!("  bench         how to regenerate every paper table/figure");
            Ok(())
        }
    }
}
