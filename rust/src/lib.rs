//! # fedml_he — FedML-HE reproduction
//!
//! A from-scratch reproduction of *FedML-HE: An Efficient
//! Homomorphic-Encryption-Based Privacy-Preserving Federated Learning System*
//! (Jin et al., 2023) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the federated-learning coordinator: server round
//!   manager, client workers, key authority, threshold key agreement,
//!   encryption-mask agreement, dropout handling, bandwidth simulation,
//!   metrics, and a from-scratch RNS-CKKS crypto substrate ([`ckks`]).
//! * **L2 (`python/compile/model.py`)** — JAX compute graphs (train step,
//!   evaluation, parameter sensitivity, gradient-inversion attack step and the
//!   HE aggregation graph) AOT-lowered to HLO text at build time.
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels for the aggregation
//!   hot path (modular weighted sum over RNS ciphertext limbs, plaintext
//!   weighted sum), lowered into the same HLO artifacts.
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! AOT artifacts through the PJRT CPU client (`xla` crate) and the rest of the
//! system is pure Rust.
//!
//! The paper's headline contribution, **Selective Parameter Encryption**
//! (encrypt only the top-`p` most privacy-sensitive parameters), lives in
//! [`he_agg`]; the privacy-budget analysis of §3 lives in [`privacy`].

pub mod agg_engine;
pub mod attacks;
pub mod baselines;
pub mod bench_support;
pub mod ckks;
pub mod coordinator;
pub mod crypto;
pub mod fl;
pub mod he_agg;
pub mod netsim;
pub mod obs;
pub mod privacy;
pub mod runtime;
pub mod transport;
pub mod util;

/// Crate-wide result type (thin alias over `anyhow`).
pub type Result<T> = anyhow::Result<T>;

/// Write a final model as raw f32 little-endian bytes (the `--out-model`
/// artifact the multi-process smoke compares bitwise across runs).
fn write_model(path: &str, model: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(model.len() * 4);
    for v in model {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, bytes)
        .map_err(|e| anyhow::anyhow!("cannot write model to {path}: {e}"))
}

/// Poll for a file another process writes (serve's task-key/addr files).
fn wait_for_file(path: &std::path::Path, wait: std::time::Duration) -> Result<()> {
    let deadline = std::time::Instant::now() + wait;
    while !path.exists() {
        anyhow::ensure!(
            std::time::Instant::now() < deadline,
            "{} did not appear within {wait:?}",
            path.display()
        );
        std::thread::sleep(std::time::Duration::from_millis(25));
    }
    Ok(())
}

/// Parse the observability flags shared by `run`/`serve` and arm the tracer
/// before the round loop starts.
fn obs_setup(args: &util::cli::Args) -> (Option<std::path::PathBuf>, Option<std::path::PathBuf>) {
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
    let report_json = args.get("report-json").map(std::path::PathBuf::from);
    if trace_out.is_some() {
        obs::trace::set_enabled(true);
    }
    (trace_out, report_json)
}

/// Flush the `--trace-out` / `--report-json` artifacts after a run.
fn obs_finish(
    trace_out: Option<&std::path::Path>,
    report_json: Option<&std::path::Path>,
    report: &coordinator::FlReport,
) -> Result<()> {
    if let Some(p) = trace_out {
        obs::write_chrome_trace(p)?;
    }
    if let Some(p) = report_json {
        obs::write_run_report(p, report.to_json())?;
    }
    Ok(())
}

/// CLI dispatch for the `fedml-he` binary.
pub fn dispatch(args: util::cli::Args) -> Result<()> {
    if let Some(lvl) = args.get("log-level") {
        util::logging::set_level(util::logging::Level::parse(lvl)?);
    } else if args.flag("verbose") {
        util::logging::set_level(util::logging::Level::Debug);
    }
    let artifacts = args.get_or("artifacts", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    let (sub, _rest) = args.subcommand();
    match sub {
        Some("run") => {
            let cfg = coordinator::FlConfig::from_args(&args)?;
            let (trace_out, report_json) = obs_setup(&args);
            let rt_holder;
            let (report, global) = if cfg.model == fl::SYNTHETIC_MODEL {
                coordinator::FlServer::standalone(cfg)?.run()?
            } else {
                rt_holder = runtime::Runtime::new(&artifacts)?;
                coordinator::FlServer::new(&rt_holder, cfg)?.run()?
            };
            if let Some(p) = args.get("out-model") {
                write_model(p, &global)?;
            }
            obs_finish(trace_out.as_deref(), report_json.as_deref(), &report)?;
            println!("{}", report.to_json());
            Ok(())
        }
        Some("serve") => {
            // Multi-process server: write the out-of-band task key, listen,
            // and drive N independent `join` processes (DESIGN.md §9).
            let mut cfg = coordinator::FlConfig::from_args(&args)?;
            cfg.transport = coordinator::Transport::Tcp;
            let key_path = args.get("task-key").ok_or_else(|| {
                anyhow::anyhow!("serve requires --task-key PATH (the out-of-band key file)")
            })?;
            let opts = coordinator::ServeOptions {
                task_key: std::path::PathBuf::from(key_path),
                addr_file: args.get("addr-file").map(std::path::PathBuf::from),
            };
            let (trace_out, report_json) = obs_setup(&args);
            let _ticker = match args.get_parsed_or("stats-every", 30.0f64) {
                secs if secs > 0.0 => Some(obs::StatsTicker::start(
                    std::time::Duration::from_secs_f64(secs),
                )),
                _ => None,
            };
            let rt_holder;
            let (report, global) = if cfg.model == fl::SYNTHETIC_MODEL {
                coordinator::FlServer::standalone(cfg)?.serve(&opts)?
            } else {
                rt_holder = runtime::Runtime::new(&artifacts)?;
                coordinator::FlServer::new(&rt_holder, cfg)?.serve(&opts)?
            };
            if let Some(p) = args.get("out-model") {
                write_model(p, &global)?;
            }
            obs_finish(trace_out.as_deref(), report_json.as_deref(), &report)?;
            println!("{}", report.to_json());
            Ok(())
        }
        Some("join") => {
            // One client process of a multi-process run: read the task key
            // distributed out-of-band, dial the serve process, and run the
            // client session loop to completion.
            let key_path = args
                .get("task-key")
                .ok_or_else(|| anyhow::anyhow!("join requires --task-key PATH"))?;
            let client_id: u64 = args
                .parsed("client-id")?
                .ok_or_else(|| anyhow::anyhow!("join requires --client-id K (0..clients)"))?;
            let wait = std::time::Duration::from_secs_f64(
                args.get_parsed_or("key-wait", 30.0f64).max(0.0),
            );
            wait_for_file(std::path::Path::new(key_path), wait)?;
            let (key, _params) = coordinator::TaskKey::load(std::path::Path::new(key_path))?;
            let addr = match args.get("connect") {
                Some(a) => a.to_string(),
                None => {
                    let af = args.get("addr-file").ok_or_else(|| {
                        anyhow::anyhow!("join requires --connect ADDR or --addr-file PATH")
                    })?;
                    wait_for_file(std::path::Path::new(af), wait)?;
                    std::fs::read_to_string(af)?.trim().to_string()
                }
            };
            let opts = transport::SessionOpts {
                connect_retry: std::time::Duration::from_secs_f64(
                    args.get_parsed_or("connect-retry", 30.0f64).max(1.0),
                ),
                round_wait: std::time::Duration::from_secs_f64(
                    args.get_parsed_or("round-wait", 300.0f64).max(1.0),
                ),
                // dial backoff + mid-task rejoin budget (0 = fail fast)
                connect_retries: args.get_parsed_or("connect-retries", 5u32),
                retry_base: std::time::Duration::from_millis(
                    args.get_parsed_or("retry-base-ms", 50u64).max(1),
                ),
                // the wire-auth mode, MAC key, and ct-wire mode come from
                // the task key itself, inside join_task — never from the
                // socket peer
                ..Default::default()
            };
            let rt_holder;
            let rt_opt = if key.spec.model == fl::SYNTHETIC_MODEL {
                None
            } else {
                rt_holder = runtime::Runtime::new(&artifacts)?;
                Some(&rt_holder)
            };
            let global = coordinator::join_task(&addr, client_id, &key, rt_opt, opts)?;
            if let Some(p) = args.get("out-model") {
                write_model(p, &global)?;
            }
            println!(
                "{}",
                util::json::Json::obj(vec![
                    ("client", client_id.into()),
                    ("params", global.len().into()),
                ])
            );
            Ok(())
        }
        Some("params") => {
            let ctx = ckks::CkksContext::new(
                args.get_parsed_or("n", 8192),
                args.get_parsed_or("limbs", 4),
                args.get_parsed_or("scaling-bits", 52),
            )?;
            println!(
                "{}",
                util::json::Json::obj(vec![
                    ("n", ctx.params.n.into()),
                    ("batch", ctx.batch().into()),
                    ("moduli", ctx.params.moduli.clone().into()),
                    ("scaling_bits", (ctx.params.scaling_bits as u64).into()),
                    ("log2_q", ctx.params.log2_q().into()),
                    (
                        "ciphertext_bytes",
                        ctx.params.ciphertext_bytes().into()
                    ),
                ])
            );
            Ok(())
        }
        Some("privacy-map") => {
            let rt = runtime::Runtime::new(&artifacts)?;
            let model = args.get_or("model", "lenet");
            let rtm = rt
                .manifest
                .models
                .get(&model)
                .ok_or_else(|| anyhow::anyhow!("model '{model}' has no artifacts"))?
                .clone();
            let mut trainer = fl::LocalTrainer::new(&rt, &model)?;
            let params = rt.manifest.load_init_params(&model)?;
            let data = if model == "tinybert" {
                fl::Workload::Token(fl::data::synthetic_tokens(
                    0,
                    64,
                    rtm.seq_len.unwrap_or(16),
                    rtm.vocab.unwrap_or(128),
                    args.get_parsed_or("seed", 0),
                ))
            } else {
                fl::Workload::Image(fl::data::synthetic_images(
                    0,
                    64,
                    (1, 28, 28),
                    rtm.num_classes,
                    0.5,
                    args.get_parsed_or("seed", 0),
                ))
            };
            let s = trainer.sensitivity(&params, &data)?;
            let p: f64 = args.get_parsed_or("ratio", 0.1);
            let mask = he_agg::EncryptionMask::top_p(&s, p);
            let mut sorted = s.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let total: f64 = sorted.iter().map(|&v| v as f64).sum();
            let top: f64 = sorted[..mask.encrypted_count().max(1)]
                .iter()
                .map(|&v| v as f64)
                .sum();
            println!(
                "{}",
                util::json::Json::obj(vec![
                    ("model", model.into()),
                    ("params", s.len().into()),
                    ("ratio", p.into()),
                    ("encrypted", mask.encrypted_count().into()),
                    ("sensitivity_mass_captured", (top / total).into()),
                    ("max_sensitivity", (sorted[0] as f64).into()),
                    (
                        "median_sensitivity",
                        (sorted[sorted.len() / 2] as f64).into()
                    ),
                ])
            );
            Ok(())
        }
        Some("stats") => {
            // Query a live coordinator's metrics over the session protocol
            // (STATS frame; no task key needed — counters are not secret).
            let addr = match args.get("connect") {
                Some(a) => a.to_string(),
                None => {
                    let af = args.get("addr-file").ok_or_else(|| {
                        anyhow::anyhow!("stats requires --connect ADDR or --addr-file PATH")
                    })?;
                    std::fs::read_to_string(af)?.trim().to_string()
                }
            };
            let timeout = std::time::Duration::from_secs_f64(
                args.get_parsed_or("timeout", 10.0f64).max(0.1),
            );
            let snapshot = transport::query_stats(&addr, timeout)?;
            println!("{snapshot}");
            // wire-security counters at a glance (also inside the JSON)
            let count = |k: &str| snapshot.get(k).and_then(|v| v.as_u64()).unwrap_or(0);
            eprintln!(
                "wire: auth_rejects {} replay_rejects {} chaos_injected {}",
                count("auth_rejects"),
                count("replay_rejects"),
                count("chaos_injected")
            );
            Ok(())
        }
        Some("bench") => {
            eprintln!("benchmarks are cargo bench targets; run e.g.:");
            eprintln!("  cargo bench --bench table4_models");
            eprintln!("  cargo bench --bench perf_hotpath   # incl. sequential-vs-pipeline shards");
            eprintln!("see DESIGN.md §5 for the complete table/figure → bench mapping");
            Ok(())
        }
        Some(other) => anyhow::bail!(
            "unknown subcommand '{other}' (expected: run | serve | join | stats | params | \
             privacy-map | bench)"
        ),
        None => {
            eprintln!("fedml-he — FedML-HE reproduction (Rust + JAX + Pallas via PJRT)");
            eprintln!();
            eprintln!("usage: fedml-he <subcommand> [--options] [--log-level error|warn|info|debug]");
            eprintln!();
            eprintln!("subcommands:");
            eprintln!("  run           run a federated task (--model --clients --rounds --ratio");
            eprintln!("                --selection topp|random|full|none --mask-granularity param|layer");
            eprintln!("                --backend xla|native");
            eprintln!("                --keys single|threshold --bandwidth ib|sar|mar|aws200");
            eprintln!("                --dropout P --dp-scale B");
            eprintln!("                --engine sequential|pipeline --shards S --quorum K");
            eprintln!("                --straggler-timeout SECS --population N");
            eprintln!("                --transport sim|tcp --listen ADDR --connect ADDR");
            eprintln!("                --wire-auth none|mac --ct-wire dense|seed");
            eprintln!("                --connect-retries N --retry-base-ms MS");
            eprintln!("                --intake-max-wait SECS --synthetic-params N");
            eprintln!("                --out-model PATH ...)");
            eprintln!("                (--model synthetic needs no artifacts; --transport tcp");
            eprintln!("                runs the whole task over persistent loopback sessions)");
            eprintln!("                (--trace-out PATH --report-json PATH for observability)");
            eprintln!("  serve         multi-process server: write --task-key PATH, listen, and");
            eprintln!("                drive --clients N independent `join` processes");
            eprintln!("                (--listen ADDR --addr-file PATH --join-wait SECS");
            eprintln!("                --stats-every SECS --trace-out PATH --report-json PATH");
            eprintln!("                --out-model PATH + the `run` task options)");
            eprintln!("  join          one client process: --task-key PATH --client-id K");
            eprintln!("                (--connect ADDR | --addr-file PATH) --key-wait SECS");
            eprintln!("                --connect-retry SECS --round-wait SECS --out-model PATH");
            eprintln!("                --connect-retries N --retry-base-ms MS (rejoin budget +");
            eprintln!("                dial backoff; wire-auth + ct-wire modes ride the task key)");
            eprintln!("  stats         query a live coordinator's metrics over the session");
            eprintln!("                protocol (--connect ADDR | --addr-file PATH) --timeout SECS");
            eprintln!("  params        print the CKKS context (--n --limbs --scaling-bits)");
            eprintln!("  privacy-map   compute a model's sensitivity map summary (--model --ratio)");
            eprintln!("  bench         how to regenerate every paper table/figure");
            Ok(())
        }
    }
}
