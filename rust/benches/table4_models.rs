//! Table 4 reproduction: vanilla fully-encrypted overheads for the paper's
//! 14-model suite (3 clients, default crypto parameters).
//!
//! Absolute times differ from the paper's i7-7700; the reproduction targets
//! are the *shape*: O(n) scaling, comp ratios ~5–20× for large models
//! (higher for tiny models due to fixed ciphertext costs), comm ratio
//! ≈ 16.6× for models ≥ one packing batch.

use fedml_he::bench_support::measure_pipeline;
use fedml_he::ckks::CkksContext;
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::fl::model_meta::{ciphertext_bytes, plaintext_bytes, TABLE4_MODELS};
use fedml_he::util::{human_bytes, human_secs, table::Table};

fn main() {
    let ctx = CkksContext::default_paper().unwrap();
    let mut rng = ChaChaRng::from_seed(4, 0);
    let mut t = Table::new(
        "Table 4 — Vanilla Fully-Encrypted Models (3 clients, n=8192, L=4, Δ=2^52)",
        &[
            "Model", "Size", "HE Time", "Non-HE Time", "Comp Ratio", "Ciphertext",
            "Plaintext", "Comm Ratio", "Sampled",
        ],
    );
    for m in TABLE4_MODELS {
        // sample budget: tiny models measured fully, giants extrapolated
        let max_cts = if m.params < 1_000_000 {
            32
        } else if m.params < 200_000_000 {
            16
        } else {
            4 // llama2: per-chunk cost × exact chunk count
        };
        let cost = measure_pipeline(&ctx, 3, m.params, max_cts, &mut rng);
        t.row(vec![
            m.name.to_string(),
            m.params.to_string(),
            human_secs(cost.he_secs()),
            human_secs(cost.plain_secs),
            format!("{:.2}", cost.comp_ratio()),
            human_bytes(ciphertext_bytes(m.params, &ctx.params)),
            human_bytes(plaintext_bytes(m.params)),
            format!("{:.2}", cost.comm_ratio()),
            format!("{:.4}", cost.sample_fraction),
        ]);
    }
    t.print();
}
