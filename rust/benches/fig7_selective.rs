//! Fig. 7 reproduction: overheads vs selective-encryption ratio, for small
//! → large models (log-scale series in the paper). Both overheads should be
//! ~proportional to the encrypted fraction, converging to plaintext cost at
//! p → 0.

use fedml_he::bench_support::measure_selective;
use fedml_he::ckks::CkksContext;
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::fl::model_meta::lookup;
use fedml_he::util::{human_bytes, human_secs, table::Table};

fn main() {
    let ctx = CkksContext::default_paper().unwrap();
    let mut rng = ChaChaRng::from_seed(7, 0);
    let ratios = [0.0, 0.1, 0.3, 0.5, 0.7, 1.0];
    for name in ["lenet", "cnn", "resnet50", "vit"] {
        let m = lookup(name).unwrap();
        let mut t = Table::new(
            &format!("Fig. 7 — {} ({} params): overhead vs encryption ratio", name, m.params),
            &["Ratio", "HE+Plain Time", "Upload Bytes", "vs Full-Enc Time", "vs Full-Enc Bytes"],
        );
        let full = measure_selective(&ctx, 3, m.params, 1.0, 16, &mut rng);
        for &r in &ratios {
            let c = measure_selective(&ctx, 3, m.params, r, 16, &mut rng);
            let time = c.he_secs() + c.plain_secs;
            let full_time = full.he_secs() + full.plain_secs;
            t.row(vec![
                format!("{:.0}%", r * 100.0),
                human_secs(time),
                human_bytes(c.ct_bytes),
                format!("{:.3}", time / full_time),
                format!("{:.3}", c.ct_bytes as f64 / full.ct_bytes as f64),
            ]);
        }
        t.print();
        println!();
    }
    println!("Shape check: at 10% encryption both overheads approach plaintext aggregation,");
    println!("matching the paper's observation after Fig. 7.");
}
