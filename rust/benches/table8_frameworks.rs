//! Table 8 reproduction: framework comparison on CNN (2 Conv + 2 FC),
//! 3 clients — ours (measured), ours w/ optimization (measured), and the
//! TenSEAL/FLARE/IBMFL cost models calibrated to the paper's measurements
//! (DESIGN.md §3), plus the plaintext floor.

use fedml_he::baselines::comparators::{ALL, FLARE, IBMFL, OURS, OURS_TENSEAL};
use fedml_he::bench_support::{measure_pipeline, measure_selective};
use fedml_he::ckks::CkksContext;
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::fl::model_meta::{ciphertext_bytes, lookup, plaintext_bytes};
use fedml_he::util::{human_bytes, human_secs, table::Table};

fn main() {
    let _ = ALL;
    let ctx = CkksContext::default_paper().unwrap();
    let mut rng = ChaChaRng::from_seed(88, 0);
    let m = lookup("cnn").unwrap();
    let ours = measure_pipeline(&ctx, 3, m.params, 32, &mut rng);
    let ours_ct = ciphertext_bytes(m.params, &ctx.params);
    // "Ours (w/ Opt)": 10% selective encryption (paper's Table-8 opt row)
    let opt = measure_selective(&ctx, 3, m.params, 0.10, 32, &mut rng);

    let mut t = Table::new(
        "Table 8 — Frameworks on CNN (2 Conv + 2 FC), 3 clients",
        &["Framework", "HE Core", "KeyMgmt", "Comp", "Comm", "Multi-Party"],
    );
    t.row(vec![
        OURS.name.into(),
        OURS.he_core.into(),
        "yes".into(),
        human_secs(ours.he_secs()),
        human_bytes(ours_ct),
        "PRE-ready, ThHE".into(),
    ]);
    t.row(vec![
        "FedML-HE (w/ Opt, 10% selective)".into(),
        OURS.he_core.into(),
        "yes".into(),
        human_secs(opt.he_secs() + opt.plain_secs),
        human_bytes(opt.ct_bytes),
        "PRE-ready, ThHE".into(),
    ]);
    for f in [OURS_TENSEAL, FLARE, IBMFL] {
        t.row(vec![
            f.name.into(),
            f.he_core.into(),
            if f.key_management { "yes" } else { "local sim" }.into(),
            format!("{} (cost model)", human_secs(f.comp_secs(ours.he_secs()))),
            format!("{} (cost model)", human_bytes(f.comm_bytes(ours_ct))),
            "-".into(),
        ]);
    }
    t.row(vec![
        "Plaintext".into(),
        "-".into(),
        "-".into(),
        human_secs(ours.plain_secs),
        human_bytes(plaintext_bytes(m.params)),
        "-".into(),
    ]);
    t.print();
    println!("\nShape check: ours < FLARE < IBMFL ≈ ours-TenSEAL in compute; IBMFL smallest");
    println!("ciphertexts; optimization cuts both by ~6-10x — the paper's Table 8 ordering.");
}
