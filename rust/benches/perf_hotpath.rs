//! §Perf harness: microbenchmarks of every hot path across the three layers.
//!
//! L1/L3 aggregation: native Rust vs the XLA Pallas artifact (single and
//! batched), in ciphertexts/second. L3 crypto: NTT, encrypt, decrypt,
//! weighted-sum throughput. Results feed EXPERIMENTS.md §Perf.

use fedml_he::agg_engine::{Arrival, Engine, EngineConfig, StreamingAggregator};
use fedml_he::bench_support::time_iters;
use fedml_he::ckks::{encrypt, ops, CkksContext};
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::he_agg::{native, selective::SelectiveCodec, xla::XlaAggregator, EncryptionMask};
use fedml_he::util::table::Table;
use std::sync::Arc;

fn main() {
    let ctx = CkksContext::default_paper().unwrap();
    let mut rng = ChaChaRng::from_seed(99, 0);
    let (pk, sk) = ctx.keygen(&mut rng);
    let values: Vec<f64> = (0..ctx.batch()).map(|i| (i as f64) * 1e-4).collect();

    let mut t = Table::new("§Perf — crypto primitive microbenchmarks (n=8192, L=4)", &[
        "Primitive", "Time", "Throughput",
    ]);

    // NTT
    let mut poly = fedml_he::ckks::RnsPoly::sample_uniform(&ctx.params, &mut rng);
    let ntt_s = time_iters(50, || {
        poly.to_ntt(&ctx.params);
        poly.from_ntt(&ctx.params);
    }) / 2.0;
    t.row(vec![
        "NTT (4 limbs, one direction)".into(),
        fedml_he::util::human_secs(ntt_s),
        format!("{:.1} MB/s limbs", 4.0 * 8192.0 * 8.0 / ntt_s / 1e6),
    ]);

    // encrypt / decrypt
    let pt = ctx.encoder.encode(&values);
    let enc_s = time_iters(20, || {
        std::hint::black_box(encrypt::encrypt(&ctx.params, &pk, &pt, values.len(), &mut rng));
    });
    let ct = encrypt::encrypt(&ctx.params, &pk, &pt, values.len(), &mut rng);
    let dec_s = time_iters(20, || {
        std::hint::black_box(encrypt::decrypt(&ctx.params, &sk, &ct));
    });
    t.row(vec![
        "Encrypt (1 ct = 4096 values)".into(),
        fedml_he::util::human_secs(enc_s),
        format!("{:.2} Mvalues/s", 4096.0 / enc_s / 1e6),
    ]);
    t.row(vec![
        "Decrypt".into(),
        fedml_he::util::human_secs(dec_s),
        format!("{:.2} Mvalues/s", 4096.0 / dec_s / 1e6),
    ]);

    // native weighted sum, 8 clients
    let n_clients = 8;
    let cts: Vec<_> = (0..n_clients)
        .map(|_| encrypt::encrypt(&ctx.params, &pk, &pt, values.len(), &mut rng))
        .collect();
    let alphas = vec![1.0 / n_clients as f64; n_clients];
    let agg_s = time_iters(20, || {
        std::hint::black_box(ops::weighted_sum(&cts, &alphas, &ctx.params));
    });
    t.row(vec![
        format!("Native weighted-sum ({n_clients} clients, 1 ct)"),
        fedml_he::util::human_secs(agg_s),
        format!("{:.1} ct/s", 1.0 / agg_s),
    ]);
    t.print();

    // §Perf — mask layout: interval runs vs the seed index-list layout at
    // ResNet-50 and BERT scale (layer-structured masks, p = 0.1). Gather =
    // compact a flat parameter vector into (encrypt staging, plaintext
    // remainder); scatter = the inverse merge. No HE inside the timed loop —
    // this isolates the layout's memory-traffic cost, plus the mask wire
    // bytes of the Algorithm-1 round-1 distribution message.
    {
        let mut t = Table::new(
            "§Perf — mask gather/scatter + wire bytes (p=0.1, layer-granularity)",
            &["Model", "Layout", "Gather", "Scatter", "Mask wire"],
        );
        for name in ["resnet50", "bert"] {
            let info = fedml_he::fl::model_meta::lookup(name).unwrap();
            let total = info.params as usize;
            let spans = info.layer_spans();
            let scores: Vec<f32> =
                (0..spans.len()).map(|i| ((i * 37) % 101) as f32).collect();
            let mask =
                fedml_he::he_agg::EncryptionMask::from_layer_scores(total, &scores, &spans, 0.1);
            let k = mask.encrypted_count();
            let params: Vec<f32> = (0..total).map(|i| ((i & 0xffff) as f32) * 1e-4).collect();
            // the seed layout: one sorted u32 per encrypted parameter
            let indices: Vec<u32> = mask.encrypted.iter_indices().map(|i| i as u32).collect();

            // index-list gather (per-index indirection; dense bool view for
            // the plaintext complement — the seed encrypt path)
            let idx_gather_s = time_iters(3, || {
                let enc: Vec<f64> =
                    indices.iter().map(|&i| params[i as usize] as f64).collect();
                let mut dense = vec![false; total];
                for &i in &indices {
                    dense[i as usize] = true;
                }
                let plain: Vec<f32> = params
                    .iter()
                    .enumerate()
                    .filter_map(|(i, &v)| (!dense[i]).then_some(v))
                    .collect();
                std::hint::black_box((enc, plain));
            });
            // run gather (contiguous segment copies — the new encrypt path)
            let run_gather_s = time_iters(3, || {
                let mut enc: Vec<f64> = Vec::with_capacity(k);
                for r in mask.runs() {
                    enc.extend(params[r.lo..r.hi].iter().map(|&v| v as f64));
                }
                let plain_layout = mask.plaintext_layout();
                let mut plain: Vec<f32> = Vec::with_capacity(total - k);
                for r in plain_layout.runs() {
                    plain.extend_from_slice(&params[r.lo..r.hi]);
                }
                std::hint::black_box((enc, plain));
            });

            // compacted buffers for the scatter direction
            let mut enc_c: Vec<f64> = Vec::with_capacity(k);
            let mut plain_c: Vec<f32> = Vec::with_capacity(total - k);
            for r in mask.runs() {
                enc_c.extend(params[r.lo..r.hi].iter().map(|&v| v as f64));
            }
            for r in mask.plaintext_layout().runs() {
                plain_c.extend_from_slice(&params[r.lo..r.hi]);
            }

            // index-list scatter (the seed decrypt path: recompute the
            // plaintext index list, then per-index writes)
            let idx_scatter_s = time_iters(3, || {
                let mut out = vec![0.0f32; total];
                let mut dense = vec![false; total];
                for &i in &indices {
                    dense[i as usize] = true;
                }
                let mut slot = 0usize;
                for (i, d) in dense.iter().enumerate() {
                    if !*d {
                        out[i] = plain_c[slot];
                        slot += 1;
                    }
                }
                for (cursor, &i) in indices.iter().enumerate() {
                    out[i as usize] = enc_c[cursor] as f32;
                }
                std::hint::black_box(out);
            });
            // run scatter (segment memcpy + widening segment loop)
            let run_scatter_s = time_iters(3, || {
                let mut out = vec![0.0f32; total];
                let mut off = 0usize;
                for r in mask.plaintext_layout().runs() {
                    out[r.lo..r.hi].copy_from_slice(&plain_c[off..off + r.len()]);
                    off += r.len();
                }
                let mut off = 0usize;
                for r in mask.runs() {
                    for (d, &s) in out[r.lo..r.hi].iter_mut().zip(enc_c[off..off + r.len()].iter())
                    {
                        *d = s as f32;
                    }
                    off += r.len();
                }
                std::hint::black_box(out);
            });

            let seed_wire = 8 + 4 * k;
            t.row(vec![
                name.into(),
                "index list (seed)".into(),
                fedml_he::util::human_secs(idx_gather_s),
                fedml_he::util::human_secs(idx_scatter_s),
                fedml_he::util::human_bytes(seed_wire as u64),
            ]);
            t.row(vec![
                name.into(),
                format!("runs ({})", mask.encrypted.n_runs()),
                fedml_he::util::human_secs(run_gather_s),
                fedml_he::util::human_secs(run_scatter_s),
                fedml_he::util::human_bytes(mask.to_bytes().len() as u64),
            ]);
            println!(
                "{name}: run-layout gather speedup {:.2}x, scatter speedup {:.2}x, \
                 wire {}x smaller",
                idx_gather_s / run_gather_s,
                idx_scatter_s / run_scatter_s,
                seed_wire / mask.to_bytes().len().max(1)
            );
        }
        t.print();
    }

    // §Perf — sequential engine vs sharded streaming pipeline on the
    // ResNet-50-sized workload (25.56M params = 6241 ciphertexts at batch
    // 4096). A 24-ciphertext sample per engine is measured and extrapolated
    // linearly (the linearity premise is verified by
    // bench_support::tests::linearity_holds).
    {
        let resnet = fedml_he::fl::model_meta::lookup("resnet50").unwrap();
        let codec = SelectiveCodec::new(ctx.clone());
        let sample_cts = 24usize;
        let total = sample_cts * codec.ctx.batch();
        let full_cts = (resnet.params as usize).div_ceil(codec.ctx.batch());
        let extrapolate = full_cts as f64 / sample_cts as f64;
        let mask = EncryptionMask::full(total);
        let alphas = vec![1.0 / n_clients as f64; n_clients];
        let arcs: Vec<Arc<fedml_he::he_agg::EncryptedUpdate>> = (0..n_clients)
            .map(|c| {
                let m: Vec<f32> = (0..total).map(|i| ((i + c * 13) as f32) * 1e-5).collect();
                Arc::new(codec.encrypt_update(&m, &mask, &pk, &mut rng))
            })
            .collect();
        let updates: Vec<fedml_he::he_agg::EncryptedUpdate> =
            arcs.iter().map(|a| (**a).clone()).collect();

        let mut t = Table::new(
            "§Perf — aggregation engines (8 clients, ResNet-50-sized; sampled)",
            &["Engine", "Sample time", "ct/s", "Full ResNet-50 (est.)"],
        );
        let seq_s = time_iters(3, || {
            std::hint::black_box(native::aggregate(&updates, &alphas, &codec.ctx.params));
        });
        t.row(vec![
            "sequential (seed loop)".into(),
            fedml_he::util::human_secs(seq_s),
            format!("{:.1}", sample_cts as f64 / seq_s),
            fedml_he::util::human_secs(seq_s * extrapolate),
        ]);
        let mut speedup_at = Vec::new();
        for shards in [1usize, 2, 4, 8] {
            let cfg = EngineConfig {
                engine: Engine::Pipeline,
                shards,
                quorum: None,
                straggler_timeout_secs: 5.0,
            };
            let engine = StreamingAggregator::new(&codec.ctx.params, cfg);
            let pipe_s = time_iters(3, || {
                let arrivals: Vec<Arrival> = arcs
                    .iter()
                    .enumerate()
                    .map(|(i, u)| Arrival {
                        client: i as u64,
                        alpha: alphas[i],
                        arrival_secs: i as f64 * 1e-3,
                        update: u.clone(),
                    })
                    .collect();
                std::hint::black_box(engine.aggregate(arrivals).unwrap());
            });
            speedup_at.push((shards, seq_s / pipe_s));
            t.row(vec![
                format!("pipeline, {shards} shard(s)"),
                fedml_he::util::human_secs(pipe_s),
                format!("{:.1}", sample_cts as f64 / pipe_s),
                fedml_he::util::human_secs(pipe_s * extrapolate),
            ]);
        }
        t.print();
        for (shards, speedup) in speedup_at {
            println!("pipeline/{shards} speedup over sequential: {speedup:.2}x");
        }
    }

    // XLA kernel path vs native over a multi-ciphertext model
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let rt = fedml_he::runtime::Runtime::new(dir).unwrap();
        let codec = SelectiveCodec::new(ctx);
        let total = 16 * codec.ctx.batch(); // 16 ciphertexts
        let models: Vec<Vec<f32>> = (0..n_clients)
            .map(|c| (0..total).map(|i| ((i + c) as f32) * 1e-5).collect())
            .collect();
        let mask = EncryptionMask::full(total);
        let updates: Vec<_> = models
            .iter()
            .map(|m| codec.encrypt_update(m, &mask, &pk, &mut rng))
            .collect();
        let agg = XlaAggregator::new(&rt, codec.ctx.params.clone()).unwrap();

        let mut t = Table::new(
            "§Perf — aggregation backends (8 clients, 16 ciphertexts = 64k params)",
            &["Backend", "Time", "ct/s"],
        );
        let native_s = time_iters(5, || {
            std::hint::black_box(fedml_he::he_agg::native::aggregate(
                &updates,
                &alphas,
                &codec.ctx.params,
            ));
        });
        t.row(vec![
            "Native Rust".into(),
            fedml_he::util::human_secs(native_s),
            format!("{:.1}", 16.0 / native_s),
        ]);
        let xla_s = time_iters(5, || {
            std::hint::black_box(agg.aggregate(&updates, &alphas).unwrap());
        });
        t.row(vec![
            "XLA (Pallas he_agg via PJRT)".into(),
            fedml_he::util::human_secs(xla_s),
            format!("{:.1}", 16.0 / xla_s),
        ]);
        t.print();
        println!(
            "\nnative/xla ratio: {:.2} (interpret-mode Pallas on CPU is a correctness \
             backend; TPU perf is estimated analytically in DESIGN.md §6)",
            xla_s / native_s
        );
    }
}
