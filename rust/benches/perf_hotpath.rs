//! §Perf harness: microbenchmarks of every hot path across the three layers.
//!
//! L1/L3 aggregation: native Rust vs the XLA Pallas artifact (single and
//! batched), in ciphertexts/second. L3 crypto: NTT, encrypt, decrypt,
//! weighted-sum throughput.
//!
//! The first section benchmarks the flat-limb/lazy-NTT/parallel-codec core
//! against a **vendored copy of the pre-PR (seed) implementation** — per-op
//! `Vec<Vec<u64>>` polynomials, reference (non-lazy) NTT butterflies,
//! per-call Barrett construction, sequential chunk encryption — at
//! ResNet-50/BERT scale, and emits the machine-readable `BENCH_perf.json`
//! at the repository root with both numbers. Run `--smoke` for the CI
//! variant (small shapes, same JSON schema).

use fedml_he::agg_engine::{Arrival, Engine, EngineConfig, StreamingAggregator};
use fedml_he::bench_support::time_iters;
use fedml_he::ckks::{encrypt, ops, CkksContext};
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::he_agg::{native, selective::SelectiveCodec, xla::XlaAggregator, EncryptionMask};
use fedml_he::util::json::Json;
use fedml_he::util::table::Table;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Vendored pre-PR implementation: the seed's data layout and kernels,
/// kept verbatim-in-spirit as the measured baseline. Allocation behavior,
/// butterfly structure and reduction strategy match commit `708d3c7`.
mod seed {
    use fedml_he::ckks::modarith::{add_mod, lift_signed, Barrett};
    use fedml_he::ckks::params::CBD_K;
    use fedml_he::ckks::{CkksParams, RnsPoly};
    use fedml_he::crypto::prng::ChaChaRng;

    /// The seed `RnsPoly`: one heap vector per limb.
    #[derive(Clone)]
    pub struct VecPoly {
        pub n: usize,
        pub limbs: Vec<Vec<u64>>,
        pub ntt_form: bool,
    }

    impl VecPoly {
        pub fn from_rns(p: &RnsPoly) -> Self {
            VecPoly {
                n: p.n,
                limbs: p.limbs().map(|l| l.to_vec()).collect(),
                ntt_form: p.ntt_form,
            }
        }

        fn from_signed(params: &CkksParams, coeffs: &[i64]) -> Self {
            let limbs = params
                .moduli
                .iter()
                .map(|&q| coeffs.iter().map(|&c| lift_signed(c, q)).collect())
                .collect();
            VecPoly {
                n: params.n,
                limbs,
                ntt_form: false,
            }
        }

        fn sample_ternary(params: &CkksParams, rng: &mut ChaChaRng) -> Self {
            let coeffs: Vec<i64> = (0..params.n).map(|_| rng.ternary()).collect();
            Self::from_signed(params, &coeffs)
        }

        fn sample_error(params: &CkksParams, rng: &mut ChaChaRng) -> Self {
            let coeffs: Vec<i64> = (0..params.n).map(|_| rng.cbd(CBD_K)).collect();
            Self::from_signed(params, &coeffs)
        }

        fn to_ntt(&mut self, params: &CkksParams) {
            for (l, limb) in self.limbs.iter_mut().enumerate() {
                params.ntt[l].forward_reference(limb);
            }
            self.ntt_form = true;
        }

        fn from_ntt(&mut self, params: &CkksParams) {
            for (l, limb) in self.limbs.iter_mut().enumerate() {
                params.ntt[l].inverse_reference(limb);
            }
            self.ntt_form = false;
        }

        fn mul_ntt(&self, other: &VecPoly, params: &CkksParams) -> VecPoly {
            let limbs = (0..self.limbs.len())
                .map(|l| {
                    let br = Barrett::new(params.moduli[l]);
                    self.limbs[l]
                        .iter()
                        .zip(other.limbs[l].iter())
                        .map(|(&a, &b)| br.mul(a, b))
                        .collect()
                })
                .collect();
            VecPoly {
                n: self.n,
                limbs,
                ntt_form: true,
            }
        }

        fn add_assign(&mut self, other: &VecPoly, params: &CkksParams) {
            for l in 0..self.limbs.len() {
                let q = params.moduli[l];
                for j in 0..self.n {
                    self.limbs[l][j] = add_mod(self.limbs[l][j], other.limbs[l][j], q);
                }
            }
        }

        /// Add a flat-layout plaintext without converting it first — keeps
        /// the timed baseline free of a deep copy the seed never performed
        /// (both paths share the same encoder).
        fn add_assign_rns(&mut self, other: &RnsPoly, params: &CkksParams) {
            for l in 0..self.limbs.len() {
                let q = params.moduli[l];
                for (d, &s) in self.limbs[l].iter_mut().zip(other.limb(l).iter()) {
                    *d = add_mod(*d, s, q);
                }
            }
        }
    }

    /// The seed encrypt: ~7 temporary polynomials per ciphertext.
    pub fn encrypt(
        params: &CkksParams,
        pk_b: &VecPoly,
        pk_a: &VecPoly,
        pt: &RnsPoly,
        rng: &mut ChaChaRng,
    ) -> (VecPoly, VecPoly) {
        let mut u = VecPoly::sample_ternary(params, rng);
        u.to_ntt(params);
        let mut c0 = pk_b.mul_ntt(&u, params);
        c0.from_ntt(params);
        let e0 = VecPoly::sample_error(params, rng);
        c0.add_assign(&e0, params);
        c0.add_assign_rns(pt, params);
        let mut c1 = pk_a.mul_ntt(&u, params);
        c1.from_ntt(params);
        let e1 = VecPoly::sample_error(params, rng);
        c1.add_assign(&e1, params);
        (c0, c1)
    }

    /// The seed weighted sum: clone-initialized output, per-call Barrett,
    /// per-call `Vec<Vec<u64>>` weight table.
    pub fn weighted_sum(
        cts: &[&(VecPoly, VecPoly)],
        alphas: &[f64],
        params: &CkksParams,
    ) -> (VecPoly, VecPoly) {
        let weights: Vec<Vec<u64>> = alphas.iter().map(|&a| params.encode_weight(a)).collect();
        let mut out = cts[0].clone();
        for poly_idx in 0..2 {
            for l in 0..params.num_limbs() {
                let br = Barrett::new(params.moduli[l]);
                let dst = if poly_idx == 0 {
                    &mut out.0.limbs[l]
                } else {
                    &mut out.1.limbs[l]
                };
                let w0 = weights[0][l];
                let src0 = if poly_idx == 0 {
                    &cts[0].0.limbs[l]
                } else {
                    &cts[0].1.limbs[l]
                };
                for (d, &s) in dst.iter_mut().zip(src0.iter()) {
                    *d = br.mul(s, w0);
                }
                for (i, ct) in cts.iter().enumerate().skip(1) {
                    let w = weights[i][l];
                    let src = if poly_idx == 0 { &ct.0.limbs[l] } else { &ct.1.limbs[l] };
                    for (d, &s) in dst.iter_mut().zip(src.iter()) {
                        *d += br.mul(s, w);
                    }
                }
                for x in dst.iter_mut() {
                    *x = br.reduce(*x);
                }
            }
        }
        out
    }
}

/// Flat-core vs seed-baseline comparison; emits `BENCH_perf.json` at the
/// repository root and returns after printing (the only section run in
/// `--smoke` mode).
fn run_core(smoke: bool) {
    let (ctx, n_clients, sample_cts, iters) = if smoke {
        (CkksContext::new(1024, 3, 40).unwrap(), 3usize, 2usize, 1usize)
    } else {
        (CkksContext::default_paper().unwrap(), 8, 12, 3)
    };
    let params = &ctx.params;
    let batch = ctx.batch();
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let mut rng = ChaChaRng::from_seed(2024, 0);
    let (pk, sk) = ctx.keygen(&mut rng);

    // --- primitive: reference (seed) vs lazy-reduction NTT on one limb.
    let q = params.moduli[0];
    let mut buf: Vec<u64> = (0..params.n).map(|_| rng.uniform_u64(q)).collect();
    let ntt_iters = if smoke { 20 } else { 200 };
    let ntt_ref_s = time_iters(ntt_iters, || {
        params.ntt[0].forward_reference(&mut buf);
        params.ntt[0].inverse_reference(&mut buf);
    }) / 2.0;
    let ntt_lazy_s = time_iters(ntt_iters, || {
        params.ntt[0].forward(&mut buf);
        params.ntt[0].inverse(&mut buf);
    }) / 2.0;

    // --- primitive: scalar vs vector butterflies on the same lazy NTT
    // (the §Perf SIMD delta; falls back to scalar-vs-scalar on hosts
    // without a vector unit, reported via the kernel name below).
    let scalar_k = fedml_he::ckks::simd::scalar();
    let ntt_scalar_s = time_iters(ntt_iters, || {
        params.ntt[0].forward_with(scalar_k, &mut buf);
        params.ntt[0].inverse_with(scalar_k, &mut buf);
    }) / 2.0;
    let simd_k = fedml_he::ckks::simd::detected_simd();
    let ntt_simd_s = match simd_k {
        Some(k) => {
            time_iters(ntt_iters, || {
                params.ntt[0].forward_with(k, &mut buf);
                params.ntt[0].inverse_with(k, &mut buf);
            }) / 2.0
        }
        None => ntt_scalar_s,
    };
    let simd_name = simd_k.map_or("scalar", |k| k.name());

    // --- packing: run-aware vs chunk-aligned ciphertext counts for the
    // BERT layer mask (p = 0.1). Pure layout arithmetic — deterministic and
    // identical in smoke and full mode, so CI diffs the values exactly.
    let bert = fedml_he::fl::model_meta::lookup("bert").unwrap();
    let spans = bert.layer_spans();
    let scores: Vec<f32> = (0..spans.len()).map(|i| ((i * 37) % 101) as f32).collect();
    let bert_mask = fedml_he::he_agg::EncryptionMask::from_layer_scores(
        bert.params as usize,
        &scores,
        &spans,
        0.1,
    );
    let pack_batch = 4096usize;
    let run_aware = fedml_he::he_agg::PackingPlan::run_aware(bert_mask.runs(), pack_batch);
    let chunk_aligned = fedml_he::he_agg::PackingPlan::chunk_aligned(bert_mask.runs(), pack_batch);

    // --- uplink wire: dense (shard form) vs seed-expanded ciphertext
    // serialization. Byte counts are pure layout arithmetic over the paper
    // parameters (n = 8192, 4 limbs, batch 4096) — deterministic and
    // identical in smoke and full mode, so CI diffs them exactly and gates
    // the compression ratio. Timings measure one ciphertext's
    // encrypt+serialize on the bench context and extrapolate to the model's
    // ciphertext count.
    let paper_n = 8192usize;
    let paper_limbs = 4usize;
    let paper_batch = paper_n / 2;
    let dense_ct_bytes =
        fedml_he::ckks::serialize::shard_header_bytes() + 2 * paper_limbs * paper_n * 4;
    let seeded_ct_bytes =
        fedml_he::ckks::serialize::seeded_header_bytes() + paper_limbs * paper_n * 4;
    let wire_vals: Vec<f64> = (0..batch).map(|i| (i as f64) * 1e-4).collect();
    let wire_pt = ctx.encoder.encode(&wire_vals);
    let mut wire_rng = ChaChaRng::from_seed(7, 3);
    let mut wire_sc = fedml_he::ckks::CkksScratch::new(params);
    let mut wire_ct = fedml_he::ckks::Ciphertext::zero(params);
    let mut wire_buf: Vec<u8> = Vec::new();
    let wire_iters = if smoke { 4 } else { 40 };
    let dense_ct_s = time_iters(wire_iters, || {
        fedml_he::ckks::encrypt_into(
            params,
            &pk,
            &wire_pt,
            batch,
            &mut wire_rng,
            &mut wire_sc,
            &mut wire_ct,
        );
        wire_buf.clear();
        fedml_he::ckks::serialize::ciphertext_shard_append(
            &wire_ct,
            0,
            params.num_limbs(),
            &mut wire_buf,
        );
        std::hint::black_box(wire_buf.len());
    });
    let seed_ct_s = time_iters(wire_iters, || {
        fedml_he::ckks::encrypt_sym_seeded_into(
            params,
            &sk,
            &wire_pt,
            batch,
            &mut wire_rng,
            &mut wire_sc,
            &mut wire_ct,
        );
        wire_buf.clear();
        fedml_he::ckks::serialize::ciphertext_seeded_append(&wire_ct, &mut wire_buf);
        std::hint::black_box(wire_buf.len());
    });
    let mut uplink_models: BTreeMap<String, Json> = BTreeMap::new();
    for (wname, total_params) in [("resnet50", 25_557_032u64), ("bert", 109_482_240u64)] {
        let cts = (total_params as usize).div_ceil(paper_batch);
        let dense_bytes = cts * dense_ct_bytes;
        let seed_bytes = cts * seeded_ct_bytes;
        uplink_models.insert(
            wname.to_string(),
            Json::obj(vec![
                ("params", total_params.into()),
                ("cts", cts.into()),
                ("dense_bytes", dense_bytes.into()),
                ("seed_bytes", seed_bytes.into()),
                (
                    "seed_to_dense_ratio",
                    (seed_bytes as f64 / dense_bytes as f64).into(),
                ),
                ("dense_encrypt_serialize_s", (dense_ct_s * cts as f64).into()),
                ("seed_encrypt_serialize_s", (seed_ct_s * cts as f64).into()),
            ]),
        );
    }

    let pk_b = seed::VecPoly::from_rns(&pk.b_ntt);
    let pk_a = seed::VecPoly::from_rns(&pk.a_ntt);

    let model_list: Vec<(&str, u64)> = if smoke {
        vec![("tiny", (4 * batch) as u64)]
    } else {
        vec![("resnet50", 25_557_032), ("bert", 109_482_240)]
    };
    let alphas = vec![1.0 / n_clients as f64; n_clients];

    let mut t = Table::new(
        "§Perf — flat-limb core vs seed baseline (encrypt one client + aggregate, extrapolated)",
        &["Model", "Seed encrypt", "Seed agg", "Flat encrypt", "Flat agg", "Speedup"],
    );
    let mut models_json: BTreeMap<String, Json> = BTreeMap::new();
    for (name, total_params) in &model_list {
        let full_cts = (*total_params as usize).div_ceil(batch);
        let s_cts = sample_cts.min(full_cts).max(1);
        let extrapolate = full_cts as f64 / s_cts as f64;
        let total = s_cts * batch;
        let values: Vec<f32> = (0..total).map(|i| ((i % 65536) as f32) * 1e-4).collect();
        let values64: Vec<f64> = values.iter().map(|&v| v as f64).collect();
        let mask = EncryptionMask::full(total);

        // Baseline: seed-style sequential chunk encryption (one client).
        let mut rng_b = ChaChaRng::from_seed(7, 1);
        let base_enc_s = time_iters(iters, || {
            for chunk in values64.chunks(batch) {
                let pt = ctx.encoder.encode(chunk);
                std::hint::black_box(seed::encrypt(params, &pk_b, &pk_a, &pt, &mut rng_b));
            }
        });
        // Baseline aggregation: seed weighted sum per chunk over n_clients.
        let seed_cts: Vec<(seed::VecPoly, seed::VecPoly)> = values64
            .chunks(batch)
            .map(|chunk| {
                let pt = ctx.encoder.encode(chunk);
                seed::encrypt(params, &pk_b, &pk_a, &pt, &mut rng_b)
            })
            .collect();
        let base_agg_s = time_iters(iters, || {
            for ct in &seed_cts {
                let group: Vec<&(seed::VecPoly, seed::VecPoly)> = vec![ct; n_clients];
                std::hint::black_box(seed::weighted_sum(&group, &alphas, params));
            }
        });

        // Optimized: parallel codec + zero-alloc kernels.
        let codec = SelectiveCodec::new(ctx.clone());
        let mut rng_o = ChaChaRng::from_seed(7, 2);
        let mut holder = None;
        let opt_enc_s = time_iters(iters, || {
            holder = Some(codec.encrypt_update(&values, &mask, &pk, &mut rng_o));
        });
        let upd = holder.unwrap();
        let updates: Vec<fedml_he::he_agg::EncryptedUpdate> =
            (0..n_clients).map(|_| upd.clone()).collect();
        let opt_agg_s = time_iters(iters, || {
            std::hint::black_box(native::aggregate(&updates, &alphas, params));
        });

        let base_total = (base_enc_s + base_agg_s) * extrapolate;
        let opt_total = (opt_enc_s + opt_agg_s) * extrapolate;
        let speedup = base_total / opt_total;
        t.row(vec![
            (*name).into(),
            fedml_he::util::human_secs(base_enc_s * extrapolate),
            fedml_he::util::human_secs(base_agg_s * extrapolate),
            fedml_he::util::human_secs(opt_enc_s * extrapolate),
            fedml_he::util::human_secs(opt_agg_s * extrapolate),
            format!("{speedup:.2}x"),
        ]);
        models_json.insert(
            (*name).to_string(),
            Json::obj(vec![
                ("params", (*total_params).into()),
                ("total_cts", full_cts.into()),
                ("sample_cts", s_cts.into()),
                (
                    "baseline",
                    Json::obj(vec![
                        ("encrypt_s", (base_enc_s * extrapolate).into()),
                        ("aggregate_s", (base_agg_s * extrapolate).into()),
                        ("encrypt_aggregate_s", base_total.into()),
                    ]),
                ),
                (
                    "optimized",
                    Json::obj(vec![
                        ("encrypt_s", (opt_enc_s * extrapolate).into()),
                        ("aggregate_s", (opt_agg_s * extrapolate).into()),
                        ("encrypt_aggregate_s", opt_total.into()),
                    ]),
                ),
                ("speedup", speedup.into()),
            ]),
        );
    }
    t.print();
    println!(
        "NTT one limb (n={}): reference {} vs lazy {} ({:.2}x)",
        params.n,
        fedml_he::util::human_secs(ntt_ref_s),
        fedml_he::util::human_secs(ntt_lazy_s),
        ntt_ref_s / ntt_lazy_s
    );
    println!(
        "NTT kernels (n={}): scalar {} vs {} {} ({:.2}x)",
        params.n,
        fedml_he::util::human_secs(ntt_scalar_s),
        simd_name,
        fedml_he::util::human_secs(ntt_simd_s),
        ntt_scalar_s / ntt_simd_s
    );
    println!(
        "BERT packing (p=0.1, batch {pack_batch}): run-aware {} cts at {:.4} utilization \
         vs chunk-aligned {} cts at {:.4} ({} fewer)",
        run_aware.n_cts(),
        run_aware.slot_utilization(),
        chunk_aligned.n_cts(),
        chunk_aligned.slot_utilization(),
        chunk_aligned.n_cts() - run_aware.n_cts()
    );
    println!(
        "uplink wire (n={paper_n}, {paper_limbs} limbs, batch {paper_batch}): dense {} vs \
         seeded {} per ct ({:.4}x); encrypt+serialize {} vs {} per ct",
        fedml_he::util::human_bytes(dense_ct_bytes as u64),
        fedml_he::util::human_bytes(seeded_ct_bytes as u64),
        seeded_ct_bytes as f64 / dense_ct_bytes as f64,
        fedml_he::util::human_secs(dense_ct_s),
        fedml_he::util::human_secs(seed_ct_s),
    );

    let out = Json::obj(vec![
        ("bench", "perf_hotpath".into()),
        ("mode", Json::from(if smoke { "smoke" } else { "full" })),
        ("cores", cores.into()),
        (
            "config",
            Json::obj(vec![
                ("n", params.n.into()),
                ("limbs", params.num_limbs().into()),
                ("clients", n_clients.into()),
                ("codec_workers", cores.into()),
            ]),
        ),
        (
            "primitives",
            Json::obj(vec![
                ("ntt_reference_s", ntt_ref_s.into()),
                ("ntt_lazy_s", ntt_lazy_s.into()),
                ("ntt_speedup", (ntt_ref_s / ntt_lazy_s).into()),
                ("ntt_scalar_s", ntt_scalar_s.into()),
                ("ntt_simd_s", ntt_simd_s.into()),
                ("ntt_simd_speedup", (ntt_scalar_s / ntt_simd_s).into()),
                ("ntt_kernel", simd_name.into()),
            ]),
        ),
        (
            "packing",
            Json::obj(vec![
                ("model", "bert".into()),
                ("mask_p", 0.1.into()),
                ("batch", pack_batch.into()),
                ("encrypted", bert_mask.encrypted_count().into()),
                ("run_aware_cts", run_aware.n_cts().into()),
                (
                    "run_aware_slot_utilization",
                    run_aware.slot_utilization().into(),
                ),
                ("chunk_aligned_cts", chunk_aligned.n_cts().into()),
                (
                    "chunk_aligned_slot_utilization",
                    chunk_aligned.slot_utilization().into(),
                ),
                (
                    "ct_reduction",
                    (chunk_aligned.n_cts() - run_aware.n_cts()).into(),
                ),
            ]),
        ),
        (
            "uplink_wire",
            Json::obj(vec![
                ("n", paper_n.into()),
                ("limbs", paper_limbs.into()),
                ("batch", paper_batch.into()),
                ("dense_ct_bytes", dense_ct_bytes.into()),
                ("seeded_ct_bytes", seeded_ct_bytes.into()),
                ("models", Json::Obj(uplink_models)),
            ]),
        ),
        ("models", Json::Obj(models_json)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_perf.json");
    std::fs::write(&path, format!("{out}\n")).expect("write BENCH_perf.json");
    println!("wrote {}", path.display());
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    run_core(smoke);
    if smoke {
        return;
    }

    let ctx = CkksContext::default_paper().unwrap();
    let mut rng = ChaChaRng::from_seed(99, 0);
    let (pk, sk) = ctx.keygen(&mut rng);
    let values: Vec<f64> = (0..ctx.batch()).map(|i| (i as f64) * 1e-4).collect();

    let mut t = Table::new("§Perf — crypto primitive microbenchmarks (n=8192, L=4)", &[
        "Primitive", "Time", "Throughput",
    ]);

    // NTT
    let mut poly = fedml_he::ckks::RnsPoly::sample_uniform(&ctx.params, &mut rng);
    let ntt_s = time_iters(50, || {
        poly.to_ntt(&ctx.params);
        poly.from_ntt(&ctx.params);
    }) / 2.0;
    t.row(vec![
        "NTT (4 limbs, one direction)".into(),
        fedml_he::util::human_secs(ntt_s),
        format!("{:.1} MB/s limbs", 4.0 * 8192.0 * 8.0 / ntt_s / 1e6),
    ]);

    // encrypt / decrypt
    let pt = ctx.encoder.encode(&values);
    let enc_s = time_iters(20, || {
        std::hint::black_box(encrypt::encrypt(&ctx.params, &pk, &pt, values.len(), &mut rng));
    });
    let ct = encrypt::encrypt(&ctx.params, &pk, &pt, values.len(), &mut rng);
    let dec_s = time_iters(20, || {
        std::hint::black_box(encrypt::decrypt(&ctx.params, &sk, &ct));
    });
    t.row(vec![
        "Encrypt (1 ct = 4096 values)".into(),
        fedml_he::util::human_secs(enc_s),
        format!("{:.2} Mvalues/s", 4096.0 / enc_s / 1e6),
    ]);
    t.row(vec![
        "Decrypt".into(),
        fedml_he::util::human_secs(dec_s),
        format!("{:.2} Mvalues/s", 4096.0 / dec_s / 1e6),
    ]);

    // native weighted sum, 8 clients
    let n_clients = 8;
    let cts: Vec<_> = (0..n_clients)
        .map(|_| encrypt::encrypt(&ctx.params, &pk, &pt, values.len(), &mut rng))
        .collect();
    let alphas = vec![1.0 / n_clients as f64; n_clients];
    let agg_s = time_iters(20, || {
        std::hint::black_box(ops::weighted_sum(&cts, &alphas, &ctx.params));
    });
    t.row(vec![
        format!("Native weighted-sum ({n_clients} clients, 1 ct)"),
        fedml_he::util::human_secs(agg_s),
        format!("{:.1} ct/s", 1.0 / agg_s),
    ]);
    t.print();

    // §Perf — mask layout: interval runs vs the seed index-list layout at
    // ResNet-50 and BERT scale (layer-structured masks, p = 0.1). Gather =
    // compact a flat parameter vector into (encrypt staging, plaintext
    // remainder); scatter = the inverse merge. No HE inside the timed loop —
    // this isolates the layout's memory-traffic cost, plus the mask wire
    // bytes of the Algorithm-1 round-1 distribution message.
    {
        let mut t = Table::new(
            "§Perf — mask gather/scatter + wire bytes (p=0.1, layer-granularity)",
            &["Model", "Layout", "Gather", "Scatter", "Mask wire"],
        );
        for name in ["resnet50", "bert"] {
            let info = fedml_he::fl::model_meta::lookup(name).unwrap();
            let total = info.params as usize;
            let spans = info.layer_spans();
            let scores: Vec<f32> =
                (0..spans.len()).map(|i| ((i * 37) % 101) as f32).collect();
            let mask =
                fedml_he::he_agg::EncryptionMask::from_layer_scores(total, &scores, &spans, 0.1);
            let k = mask.encrypted_count();
            let params: Vec<f32> = (0..total).map(|i| ((i & 0xffff) as f32) * 1e-4).collect();
            // the seed layout: one sorted u32 per encrypted parameter
            let indices: Vec<u32> = mask.encrypted.iter_indices().map(|i| i as u32).collect();

            // index-list gather (per-index indirection; dense bool view for
            // the plaintext complement — the seed encrypt path)
            let idx_gather_s = time_iters(3, || {
                let enc: Vec<f64> =
                    indices.iter().map(|&i| params[i as usize] as f64).collect();
                let mut dense = vec![false; total];
                for &i in &indices {
                    dense[i as usize] = true;
                }
                let plain: Vec<f32> = params
                    .iter()
                    .enumerate()
                    .filter_map(|(i, &v)| (!dense[i]).then_some(v))
                    .collect();
                std::hint::black_box((enc, plain));
            });
            // run gather (contiguous segment copies — the new encrypt path)
            let run_gather_s = time_iters(3, || {
                let mut enc: Vec<f64> = Vec::with_capacity(k);
                for r in mask.runs() {
                    enc.extend(params[r.lo..r.hi].iter().map(|&v| v as f64));
                }
                let plain_layout = mask.plaintext_layout();
                let mut plain: Vec<f32> = Vec::with_capacity(total - k);
                for r in plain_layout.runs() {
                    plain.extend_from_slice(&params[r.lo..r.hi]);
                }
                std::hint::black_box((enc, plain));
            });

            // compacted buffers for the scatter direction
            let mut enc_c: Vec<f64> = Vec::with_capacity(k);
            let mut plain_c: Vec<f32> = Vec::with_capacity(total - k);
            for r in mask.runs() {
                enc_c.extend(params[r.lo..r.hi].iter().map(|&v| v as f64));
            }
            for r in mask.plaintext_layout().runs() {
                plain_c.extend_from_slice(&params[r.lo..r.hi]);
            }

            // index-list scatter (the seed decrypt path: recompute the
            // plaintext index list, then per-index writes)
            let idx_scatter_s = time_iters(3, || {
                let mut out = vec![0.0f32; total];
                let mut dense = vec![false; total];
                for &i in &indices {
                    dense[i as usize] = true;
                }
                let mut slot = 0usize;
                for (i, d) in dense.iter().enumerate() {
                    if !*d {
                        out[i] = plain_c[slot];
                        slot += 1;
                    }
                }
                for (cursor, &i) in indices.iter().enumerate() {
                    out[i as usize] = enc_c[cursor] as f32;
                }
                std::hint::black_box(out);
            });
            // run scatter (segment memcpy + widening segment loop)
            let run_scatter_s = time_iters(3, || {
                let mut out = vec![0.0f32; total];
                let mut off = 0usize;
                for r in mask.plaintext_layout().runs() {
                    out[r.lo..r.hi].copy_from_slice(&plain_c[off..off + r.len()]);
                    off += r.len();
                }
                let mut off = 0usize;
                for r in mask.runs() {
                    for (d, &s) in out[r.lo..r.hi].iter_mut().zip(enc_c[off..off + r.len()].iter())
                    {
                        *d = s as f32;
                    }
                    off += r.len();
                }
                std::hint::black_box(out);
            });

            let seed_wire = 8 + 4 * k;
            t.row(vec![
                name.into(),
                "index list (seed)".into(),
                fedml_he::util::human_secs(idx_gather_s),
                fedml_he::util::human_secs(idx_scatter_s),
                fedml_he::util::human_bytes(seed_wire as u64),
            ]);
            t.row(vec![
                name.into(),
                format!("runs ({})", mask.encrypted.n_runs()),
                fedml_he::util::human_secs(run_gather_s),
                fedml_he::util::human_secs(run_scatter_s),
                fedml_he::util::human_bytes(mask.to_bytes().len() as u64),
            ]);
            println!(
                "{name}: run-layout gather speedup {:.2}x, scatter speedup {:.2}x, \
                 wire {}x smaller",
                idx_gather_s / run_gather_s,
                idx_scatter_s / run_scatter_s,
                seed_wire / mask.to_bytes().len().max(1)
            );
        }
        t.print();
    }

    // §Perf — sequential engine vs sharded streaming pipeline on the
    // ResNet-50-sized workload (25.56M params = 6241 ciphertexts at batch
    // 4096). A 24-ciphertext sample per engine is measured and extrapolated
    // linearly (the linearity premise is verified by
    // bench_support::tests::linearity_holds).
    {
        let resnet = fedml_he::fl::model_meta::lookup("resnet50").unwrap();
        let codec = SelectiveCodec::new(ctx.clone());
        let sample_cts = 24usize;
        let total = sample_cts * codec.ctx.batch();
        let full_cts = (resnet.params as usize).div_ceil(codec.ctx.batch());
        let extrapolate = full_cts as f64 / sample_cts as f64;
        let mask = EncryptionMask::full(total);
        let alphas = vec![1.0 / n_clients as f64; n_clients];
        let arcs: Vec<Arc<fedml_he::he_agg::EncryptedUpdate>> = (0..n_clients)
            .map(|c| {
                let m: Vec<f32> = (0..total).map(|i| ((i + c * 13) as f32) * 1e-5).collect();
                Arc::new(codec.encrypt_update(&m, &mask, &pk, &mut rng))
            })
            .collect();
        let updates: Vec<fedml_he::he_agg::EncryptedUpdate> =
            arcs.iter().map(|a| (**a).clone()).collect();

        let mut t = Table::new(
            "§Perf — aggregation engines (8 clients, ResNet-50-sized; sampled)",
            &["Engine", "Sample time", "ct/s", "Full ResNet-50 (est.)"],
        );
        let seq_s = time_iters(3, || {
            std::hint::black_box(native::aggregate(&updates, &alphas, &codec.ctx.params));
        });
        t.row(vec![
            "sequential (seed loop)".into(),
            fedml_he::util::human_secs(seq_s),
            format!("{:.1}", sample_cts as f64 / seq_s),
            fedml_he::util::human_secs(seq_s * extrapolate),
        ]);
        let mut speedup_at = Vec::new();
        for shards in [1usize, 2, 4, 8] {
            let cfg = EngineConfig {
                engine: Engine::Pipeline,
                shards,
                quorum: None,
                straggler_timeout_secs: 5.0,
            };
            let engine = StreamingAggregator::new(&codec.ctx.params, cfg);
            let pipe_s = time_iters(3, || {
                let arrivals: Vec<Arrival> = arcs
                    .iter()
                    .enumerate()
                    .map(|(i, u)| Arrival {
                        client: i as u64,
                        alpha: alphas[i],
                        arrival_secs: i as f64 * 1e-3,
                        update: u.clone(),
                    })
                    .collect();
                std::hint::black_box(engine.aggregate(arrivals).unwrap());
            });
            speedup_at.push((shards, seq_s / pipe_s));
            t.row(vec![
                format!("pipeline, {shards} shard(s)"),
                fedml_he::util::human_secs(pipe_s),
                format!("{:.1}", sample_cts as f64 / pipe_s),
                fedml_he::util::human_secs(pipe_s * extrapolate),
            ]);
        }
        t.print();
        for (shards, speedup) in speedup_at {
            println!("pipeline/{shards} speedup over sequential: {speedup:.2}x");
        }
    }

    // XLA kernel path vs native over a multi-ciphertext model
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let rt = fedml_he::runtime::Runtime::new(dir).unwrap();
        let codec = SelectiveCodec::new(ctx);
        let total = 16 * codec.ctx.batch(); // 16 ciphertexts
        let models: Vec<Vec<f32>> = (0..n_clients)
            .map(|c| (0..total).map(|i| ((i + c) as f32) * 1e-5).collect())
            .collect();
        let mask = EncryptionMask::full(total);
        let updates: Vec<_> = models
            .iter()
            .map(|m| codec.encrypt_update(m, &mask, &pk, &mut rng))
            .collect();
        let agg = XlaAggregator::new(&rt, codec.ctx.params.clone()).unwrap();

        let mut t = Table::new(
            "§Perf — aggregation backends (8 clients, 16 ciphertexts = 64k params)",
            &["Backend", "Time", "ct/s"],
        );
        let native_s = time_iters(5, || {
            std::hint::black_box(fedml_he::he_agg::native::aggregate(
                &updates,
                &alphas,
                &codec.ctx.params,
            ));
        });
        t.row(vec![
            "Native Rust".into(),
            fedml_he::util::human_secs(native_s),
            format!("{:.1}", 16.0 / native_s),
        ]);
        let xla_s = time_iters(5, || {
            std::hint::black_box(agg.aggregate(&updates, &alphas).unwrap());
        });
        t.row(vec![
            "XLA (Pallas he_agg via PJRT)".into(),
            fedml_he::util::human_secs(xla_s),
            format!("{:.1}", 16.0 / xla_s),
        ]);
        t.print();
        println!(
            "\nnative/xla ratio: {:.2} (interpret-mode Pallas on CPU is a correctness \
             backend; TPU perf is estimated analytically in DESIGN.md §6)",
            xla_s / native_s
        );
    }
}
