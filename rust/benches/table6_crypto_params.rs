//! Table 6 reproduction: computational & communication overhead and model-
//! accuracy impact across crypto-parameter setups — packing batch size
//! {1024, 2048, 4096} × scaling bits {14, 20, 33, 40, 52}, CNN-sized model,
//! 3 clients.
//!
//! Accuracy Δ is measured end-to-end: two short FL runs on the mlp artifact
//! with identical seeds — plaintext aggregation vs full-HE aggregation under
//! the swept context (native backend; the XLA artifact is fixed-shape) —
//! and the final test accuracies differenced, exactly the paper's metric.

use fedml_he::bench_support::measure_pipeline;
use fedml_he::ckks::CkksContext;
use fedml_he::coordinator::{Backend, FlConfig, FlServer, Selection};
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::runtime::Runtime;
use fedml_he::util::{human_bytes, human_secs, table::Table};

fn accuracy_delta(rt: &Runtime, n: usize, bits: u32) -> Option<f64> {
    let base = FlConfig {
        model: "mlp".into(),
        clients: 3,
        rounds: 4,
        local_steps: 2,
        lr: 0.1,
        samples_per_client: 96,
        eval_every: 4,
        backend: Backend::Native,
        dropout: 0.0,
        ..Default::default()
    };
    let mut plain_cfg = base.clone();
    plain_cfg.selection = Selection::None;
    let mut he_cfg = base;
    he_cfg.selection = Selection::Full;
    he_cfg.crypto_override = Some((n, 4, bits));
    let (pr, _) = FlServer::new(rt, plain_cfg).ok()?.run().ok()?;
    let (hr, _) = FlServer::new(rt, he_cfg).ok()?.run().ok()?;
    let pa = pr.evals.last()?.accuracy as f64;
    let ha = hr.evals.last()?.accuracy as f64;
    Some((ha - pa) * 100.0)
}

fn main() {
    let params = fedml_he::fl::model_meta::lookup("cnn").unwrap().params;
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = artifacts
        .join("manifest.json")
        .exists()
        .then(|| Runtime::new(&artifacts).ok())
        .flatten();

    let mut t = Table::new(
        "Table 6 — Crypto-parameter sweep (CNN-sized, 3 clients)",
        &["Batch", "Scaling Bits", "Comp (s)", "Comm", "Test Acc Δ (%)"],
    );
    // The paper sweeps the HE packing batch size at a fixed ring: fewer
    // values packed per ciphertext ⇒ more (identically-sized) ciphertexts.
    // We model that as n_cts = params/batch at the default n = 8192 ring:
    // comp and comm both scale by 4096/batch, exactly the paper's halving.
    for batch in [1024usize, 2048, 4096] {
        let fill = 4096 / batch; // ciphertext multiplier vs full packing
        for bits in [14u32, 20, 33, 40, 52] {
            let ctx = CkksContext::new(8192, 4, bits).unwrap();
            let mut rng = ChaChaRng::from_seed(6, bits as u64);
            let effective = params * fill as u64;
            let cost = measure_pipeline(&ctx, 3, effective, 8, &mut rng);
            // accuracy runs use a ring whose quantization matches the batch
            let acc = rt
                .as_ref()
                .and_then(|rt| accuracy_delta(rt, 2 * batch, bits))
                .map(|d| format!("{d:+.2}"))
                .unwrap_or_else(|| "n/a (no artifacts)".into());
            t.row(vec![
                batch.to_string(),
                bits.to_string(),
                human_secs(cost.he_secs()),
                human_bytes(fedml_he::fl::model_meta::ciphertext_bytes(
                    effective,
                    &ctx.params,
                )),
                acc,
            ]);
        }
    }
    t.print();
    println!("\nShape check: larger batch ⇒ faster + smaller (packing efficiency);");
    println!("scaling bits barely move overheads; low bits (14) risk accuracy wobble —");
    println!("the paper's Table 6 conclusions.");
}
