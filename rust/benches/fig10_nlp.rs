//! Fig. 10 reproduction (analog): language-model inversion on the tinybert
//! artifact — token recovery rate from the embedding gradient, top-s
//! sensitive masking vs random masking.

use fedml_he::attacks::nlp::{recover_tokens, score_recovery};
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::fl::data::synthetic_tokens;
use fedml_he::he_agg::EncryptionMask;
use fedml_he::runtime::executor::{Arg, Runtime};
use fedml_he::util::table::Table;

fn main() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("fig10: artifacts not built (run `make artifacts`)");
        return;
    }
    let rt = Runtime::new(dir).unwrap();
    let meta = &rt.manifest.models["tinybert"];
    let (vocab, d_model) = (meta.vocab.unwrap(), 32usize);
    let params = rt.manifest.load_init_params("tinybert").unwrap();
    let data = synthetic_tokens(0, 64, meta.seq_len.unwrap(), vocab, 10);
    let b = rt.manifest.train_batch;
    // victim batch = ONE sequence replicated (the Fig. 10 single-sentence
    // setting); only its ~16 distinct tokens are present in the gradient.
    let (x1, y1) = data.batch(0, 1);
    let (mut x, mut y) = (Vec::new(), Vec::new());
    for _ in 0..b {
        x.extend_from_slice(&x1);
        y.extend_from_slice(&y1);
    }
    let grad = rt
        .execute(
            "tinybert_grad",
            &[
                Arg::F32(&params, vec![params.len() as i64]),
                Arg::I32(&x, vec![b as i64, meta.seq_len.unwrap() as i64]),
                Arg::I32(&y, vec![b as i64, meta.seq_len.unwrap() as i64]),
            ],
        )
        .unwrap()[0]
        .to_vec::<f32>()
        .unwrap();
    let k = rt.manifest.sens_batch;
    let (sx, sy) = data.batch(0, k);
    let sens = rt
        .execute(
            "tinybert_sens",
            &[
                Arg::F32(&params, vec![params.len() as i64]),
                Arg::I32(&sx, vec![k as i64, meta.seq_len.unwrap() as i64]),
                Arg::I32(&sy, vec![k as i64, meta.seq_len.unwrap() as i64]),
            ],
        )
        .unwrap()[0]
        .to_vec::<f32>()
        .unwrap();

    let actual: Vec<i32> = x1.clone();
    let threshold = 1e-4f32;
    let total = params.len();
    let mut t = Table::new(
        "Fig. 10 — Token recovery from embedding gradients (tinybert)",
        &["Mask", "Ratio", "Recall", "False Positives"],
    );
    let mut rng = ChaChaRng::from_seed(10, 0);
    let embed = 0..vocab * d_model;
    let head = total - (d_model * vocab + vocab)..total;
    let cases: Vec<(String, EncryptionMask)> = vec![
        ("none".into(), EncryptionMask::empty(total)),
        ("top-s 10%".into(), EncryptionMask::top_p(&sens, 0.10)),
        ("top-s 30%".into(), EncryptionMask::top_p(&sens, 0.30)),
        (
            "recipe 30%+first/last".into(),
            EncryptionMask::recipe(&sens, 0.30, embed, head),
        ),
        ("random 30%".into(), EncryptionMask::random(total, 0.30, &mut rng)),
        ("random 75%".into(), EncryptionMask::random(total, 0.75, &mut rng)),
    ];
    for (name, mask) in cases {
        let rec = recover_tokens(&grad, &mask, vocab, d_model, threshold);
        let s = score_recovery(&rec, &actual);
        t.row(vec![
            name,
            format!("{:.1}%", 100.0 * mask.ratio()),
            format!("{:.1}%", 100.0 * s.recall),
            s.false_positives.to_string(),
        ]);
    }
    t.print();
    println!("\nShape check: the Empirical Selection Recipe (top-30% + first/last layers)");
    println!("collapses recovery; random masking leaves most tokens recoverable — Fig. 10.");
}
