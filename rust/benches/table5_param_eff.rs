//! Table 5 reproduction: parameter-efficiency techniques before HE —
//! DoubleSqueeze top-k (ResNet-18, k=1M) and LoRA-style adapters (BERT).

use fedml_he::baselines::param_efficiency::{lora_params, top_k};
use fedml_he::ckks::CkksContext;
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::fl::model_meta::{ciphertext_bytes, lookup, plaintext_bytes};
use fedml_he::util::{human_bytes, table::Table};

fn main() {
    let ctx = CkksContext::default_paper().unwrap();
    let mut t = Table::new(
        "Table 5 — Parameter efficiency + HE (PT = plaintext, CT = full ciphertext)",
        &["Model", "PT", "CT (full enc)", "Opt CT", "Reduction vs CT"],
    );

    // ResNet-18 + DoubleSqueeze top-k (k = 1M)
    let r18 = lookup("resnet18").unwrap();
    let k = 1_000_000u64;
    // validate the compressor on a real vector slice
    let mut rng = ChaChaRng::from_seed(5, 0);
    let update: Vec<f32> = (0..100_000).map(|_| rng.normal_f64() as f32).collect();
    let (compressed, _residual) = top_k(&update, 10_000);
    assert_eq!(compressed.indices.len(), 10_000);
    let opt_ct = ciphertext_bytes(k, &ctx.params);
    t.row(vec![
        "ResNet-18 (12M) + DoubleSqueeze k=1M".into(),
        human_bytes(plaintext_bytes(r18.params)),
        human_bytes(ciphertext_bytes(r18.params, &ctx.params)),
        human_bytes(opt_ct),
        format!(
            "{:.2}x",
            ciphertext_bytes(r18.params, &ctx.params) as f64 / opt_ct as f64
        ),
    ]);

    // BERT + LoRA r=8 on 12 layers × 2 matrices of d=768
    let bert = lookup("bert").unwrap();
    let lora = lora_params(768, 12, 2, 8);
    let lora_ct = ciphertext_bytes(lora, &ctx.params);
    t.row(vec![
        "BERT (110M) + LoRA r=8".into(),
        human_bytes(plaintext_bytes(bert.params)),
        human_bytes(ciphertext_bytes(bert.params, &ctx.params)),
        human_bytes(lora_ct),
        format!(
            "{:.0}x",
            ciphertext_bytes(bert.params, &ctx.params) as f64 / lora_ct as f64
        ),
    ]);
    t.print();
    println!("\nShape check: parameter-efficiency cuts the encrypted payload by 1-2 orders");
    println!("of magnitude before Selective Parameter Encryption even applies (paper Tab. 5).");
}
