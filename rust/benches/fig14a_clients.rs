//! Fig. 14a reproduction: step breakdown of HE computational cost as the
//! number of clients grows to 200 (fully-encrypted CNN). The aggregation
//! step grows with N on the server; encryption stays constant per client.

use fedml_he::bench_support::measure_pipeline;
use fedml_he::ckks::CkksContext;
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::util::{human_secs, table::Table};

fn main() {
    let ctx = CkksContext::default_paper().unwrap();
    let mut rng = ChaChaRng::from_seed(14, 0);
    let params = fedml_he::fl::model_meta::lookup("cnn").unwrap().params;
    let mut t = Table::new(
        "Fig. 14a — HE cost breakdown vs number of clients (CNN, fully encrypted)",
        &["Clients", "Encrypt/client", "Server Aggregate", "Decrypt", "Agg share"],
    );
    for n in [3usize, 10, 25, 50, 100, 200] {
        let c = measure_pipeline(&ctx, n, params, 8, &mut rng);
        let total = c.encrypt_secs + c.aggregate_secs + c.decrypt_secs;
        t.row(vec![
            n.to_string(),
            human_secs(c.encrypt_secs),
            human_secs(c.aggregate_secs),
            human_secs(c.decrypt_secs),
            format!("{:.1}%", 100.0 * c.aggregate_secs / total),
        ]);
    }
    t.print();
    println!("\nShape check: server aggregation grows ~linearly with N (proportionally-added");
    println!("ciphertext inputs) while per-client encryption and decryption stay flat.");
}
