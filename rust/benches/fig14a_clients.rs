//! Fig. 14a reproduction: step breakdown of HE computational cost as the
//! number of clients grows to 200 (fully-encrypted CNN). The aggregation
//! step grows with N on the server; encryption stays constant per client.

use fedml_he::agg_engine::{
    Arrival, CohortScheduler, Engine, EngineConfig, Population, StreamingAggregator,
};
use fedml_he::bench_support::{measure_pipeline, time_iters};
use fedml_he::ckks::CkksContext;
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::he_agg::{selective::SelectiveCodec, EncryptionMask};
use fedml_he::util::{human_secs, table::Table};
use std::sync::Arc;

fn main() {
    let ctx = CkksContext::default_paper().unwrap();
    let mut rng = ChaChaRng::from_seed(14, 0);
    let params = fedml_he::fl::model_meta::lookup("cnn").unwrap().params;
    let mut t = Table::new(
        "Fig. 14a — HE cost breakdown vs number of clients (CNN, fully encrypted)",
        &["Clients", "Encrypt/client", "Server Aggregate", "Decrypt", "Agg share"],
    );
    for n in [3usize, 10, 25, 50, 100, 200] {
        let c = measure_pipeline(&ctx, n, params, 8, &mut rng);
        let total = c.encrypt_secs + c.aggregate_secs + c.decrypt_secs;
        t.row(vec![
            n.to_string(),
            human_secs(c.encrypt_secs),
            human_secs(c.aggregate_secs),
            human_secs(c.decrypt_secs),
            format!("{:.1}%", 100.0 * c.aggregate_secs / total),
        ]);
    }
    t.print();
    println!("\nShape check: server aggregation grows ~linearly with N (proportionally-added");
    println!("ciphertext inputs) while per-client encryption and decryption stay flat.");

    // Fig. 14a, population-scale point: the seed could only *instantiate*
    // its participants, capping N at memory. The cohort scheduler registers
    // a 1,000,000-client population lazily (O(1) state) and samples K=16
    // participants per round; one streamed round then aggregates the
    // cohort's updates through the pipeline engine.
    let population = 1_000_000u64;
    let k = 16usize;
    let sched = CohortScheduler::new(Population::new(population, 14), k);
    let sample_s = time_iters(1000, || {
        std::hint::black_box(sched.sample(7));
    });

    let codec = SelectiveCodec::new(ctx.clone());
    let mut rng2 = ChaChaRng::from_seed(15, 0);
    let (pk, _sk) = codec.ctx.keygen(&mut rng2);
    let cohort = sched.sample(0);
    let n_cts = 4usize; // per-update sample; HE cost extrapolates linearly
    let total = n_cts * codec.ctx.batch();
    let mask = EncryptionMask::full(total);
    let arcs: Vec<Arc<fedml_he::he_agg::EncryptedUpdate>> = cohort
        .members
        .iter()
        .map(|m| {
            let model: Vec<f32> = (0..total)
                .map(|i| ((i as u64 + m.id) % 997) as f32 * 1e-4)
                .collect();
            Arc::new(codec.encrypt_update(&model, &mask, &pk, &mut rng2))
        })
        .collect();
    let engine_cfg = EngineConfig {
        engine: Engine::Pipeline,
        shards: 4,
        quorum: None,
        straggler_timeout_secs: 5.0,
    };
    let engine = StreamingAggregator::new(&codec.ctx.params, engine_cfg);
    let round_s = time_iters(3, || {
        let arrivals: Vec<Arrival> = arcs
            .iter()
            .zip(cohort.members.iter())
            .enumerate()
            .map(|(i, (u, m))| Arrival {
                client: m.id,
                alpha: m.alpha,
                arrival_secs: i as f64 * 1e-3,
                update: u.clone(),
            })
            .collect();
        std::hint::black_box(engine.aggregate(arrivals).unwrap());
    });

    let mut t = Table::new(
        "Fig. 14a (population scale) — 1M registered clients, K=16 cohort/round",
        &["Step", "Time"],
    );
    t.row(vec![
        format!("Cohort sample (K={k} of N={population})"),
        human_secs(sample_s),
    ]);
    t.row(vec![
        format!("Streamed aggregation round ({n_cts}-ct sample, 4 shards)"),
        human_secs(round_s),
    ]);
    t.print();
    println!("\nScheduler state is O(1) in N and O(K) per round: the same bench point runs");
    println!("unchanged at N = 100M+ (see agg_engine::cohort tests).");
}
