//! Fig. 2 reproduction: computational (left) and communication (right)
//! overheads vs model size — naive FedML-HE vs Nvidia-FLARE cost model vs
//! plaintext aggregation, 3 clients.

use fedml_he::baselines::comparators::FLARE;
use fedml_he::bench_support::measure_pipeline;
use fedml_he::ckks::CkksContext;
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::fl::model_meta::{lookup, plaintext_bytes};
use fedml_he::util::{human_bytes, human_secs, table::Table};

fn main() {
    let ctx = CkksContext::default_paper().unwrap();
    let mut rng = ChaChaRng::from_seed(2, 0);
    let mut t = Table::new(
        "Fig. 2 — Naive FedML-HE vs FLARE (cost model) vs Plaintext (3 clients)",
        &["Model", "Params", "Ours (s)", "FLARE (s)", "Plain (s)", "Ours CT", "FLARE CT", "Plain"],
    );
    for name in ["mlp", "lenet", "cnn", "resnet18", "resnet50", "vit", "bert"] {
        let m = lookup(name).unwrap();
        let cost = measure_pipeline(&ctx, 3, m.params, 16, &mut rng);
        let ct = fedml_he::fl::model_meta::ciphertext_bytes(m.params, &ctx.params);
        t.row(vec![
            name.to_string(),
            m.params.to_string(),
            human_secs(cost.he_secs()),
            human_secs(FLARE.comp_secs(cost.he_secs())),
            human_secs(cost.plain_secs),
            human_bytes(ct),
            human_bytes(FLARE.comm_bytes(ct)),
            human_bytes(plaintext_bytes(m.params)),
        ]);
    }
    t.print();
    println!("\nSeries shape check: both overheads grow linearly with model size (O(n));");
    println!("ours < FLARE in comp and comm at every size, as in the paper's Fig. 2.");
    println!("(FLARE column is a cost model calibrated to the paper's Table 8 — DESIGN.md §3.)");
}
