//! Fig. 14b reproduction: impact of deployment bandwidth (IB / SAR / MAR) on
//! the communication share of a fully-encrypted ResNet-50 training cycle,
//! HE vs non-HE.

use fedml_he::bench_support::measure_pipeline;
use fedml_he::ckks::CkksContext;
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::fl::model_meta::{ciphertext_bytes, lookup, plaintext_bytes};
use fedml_he::netsim::PROFILES;
use fedml_he::util::{human_secs, table::Table};

fn main() {
    let ctx = CkksContext::default_paper().unwrap();
    let mut rng = ChaChaRng::from_seed(141, 0);
    let m = lookup("resnet50").unwrap();
    let cost = measure_pipeline(&ctx, 3, m.params, 16, &mut rng);
    let ct = 2 * ciphertext_bytes(m.params, &ctx.params); // up + down
    let pt = 2 * plaintext_bytes(m.params);
    // non-comm share of the cycle: HE ops (HE case) or nothing extra
    let he_ops = cost.he_secs();
    let other = 30.0; // fixed local-train + overhead budget, same in both

    let mut t = Table::new(
        "Fig. 14b — Bandwidth impact on fully-encrypted ResNet-50 cycles",
        &["Profile", "HE comm", "HE comm %", "Non-HE comm", "Non-HE comm %"],
    );
    for bw in PROFILES {
        let he_comm = bw.transfer_secs(ct);
        let pt_comm = bw.transfer_secs(pt);
        t.row(vec![
            bw.name.to_string(),
            human_secs(he_comm),
            format!("{:.1}%", 100.0 * he_comm / (he_comm + he_ops + other)),
            human_secs(pt_comm),
            format!("{:.1}%", 100.0 * pt_comm / (pt_comm + other)),
        ]);
    }
    t.print();
    println!("\nShape check: HE dominates low-bandwidth (MAR) cycles while medium/high-");
    println!("bandwidth deployments see limited impact — the paper's D.5 conclusion.");
}
