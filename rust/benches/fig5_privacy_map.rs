//! Fig. 5 reproduction: the model privacy map — per-layer parameter
//! sensitivity on LeNet, computed through the AOT sensitivity graph on a
//! synthetic CIFAR-like batch. The paper's qualitative claim: sensitivity is
//! strongly imbalanced, with many near-zero parameters.

use fedml_he::fl::data::synthetic_images;
use fedml_he::runtime::executor::{Arg, Runtime};
use fedml_he::util::table::Table;

// LeNet layer boundaries in the flat layout (python/compile/models.py spec)
const LAYERS: &[(&str, usize)] = &[
    ("conv1_w", 150),
    ("conv1_b", 6),
    ("conv2_w", 2400),
    ("conv2_b", 16),
    ("fc1_w", 30720),
    ("fc1_b", 120),
    ("fc2_w", 10080),
    ("fc2_b", 84),
    ("fc3_w", 840),
    ("fc3_b", 10),
];

fn main() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("fig5: artifacts not built (run `make artifacts`)");
        return;
    }
    let rt = Runtime::new(dir).unwrap();
    let params = rt.manifest.load_init_params("lenet").unwrap();
    let k = rt.manifest.sens_batch;
    let d = synthetic_images(0, k, (1, 28, 28), 10, 0.5, 5);
    let (x, y) = d.batch(0, k);
    let out = rt
        .execute(
            "lenet_sens",
            &[
                Arg::F32(&params, vec![params.len() as i64]),
                Arg::F32(&x, vec![k as i64, 1, 28, 28]),
                Arg::I32(&y, vec![k as i64]),
            ],
        )
        .unwrap();
    let s = out[0].to_vec::<f32>().unwrap();

    let mut t = Table::new(
        "Fig. 5 — LeNet privacy map (per-layer sensitivity statistics)",
        &["Layer", "Params", "Mean Sens", "Max Sens", "Near-zero %"],
    );
    let mut off = 0usize;
    for (name, len) in LAYERS {
        let layer = &s[off..off + len];
        off += len;
        let mean: f64 = layer.iter().map(|&v| v as f64).sum::<f64>() / *len as f64;
        let max = layer.iter().cloned().fold(0.0f32, f32::max);
        let near_zero =
            layer.iter().filter(|&&v| (v as f64) < 0.01 * max as f64).count() as f64
                / *len as f64;
        t.row(vec![
            name.to_string(),
            len.to_string(),
            format!("{mean:.3e}"),
            format!("{max:.3e}"),
            format!("{:.1}%", 100.0 * near_zero),
        ]);
    }
    assert_eq!(off, s.len());
    t.print();

    // imbalance summary (the Fig. 5 takeaway)
    let mut sorted = s.clone();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let total: f64 = sorted.iter().map(|&v| v as f64).sum();
    let top10: f64 = sorted[..s.len() / 10].iter().map(|&v| v as f64).sum();
    println!(
        "\nTop-10% most sensitive parameters carry {:.1}% of total sensitivity mass",
        100.0 * top10 / total
    );
    println!("Shape check: sensitivity is imbalanced; many parameters are near zero.");
}
