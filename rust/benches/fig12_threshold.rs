//! Fig. 12 reproduction: microbenchmark of the threshold-HE-based FedAvg
//! (2-party) vs the single-key variant, per pipeline stage.

use fedml_he::bench_support::{measure_pipeline, measure_threshold};
use fedml_he::ckks::CkksContext;
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::util::{human_secs, table::Table};
use std::time::Instant;

fn main() {
    let ctx = CkksContext::default_paper().unwrap();
    let mut rng = ChaChaRng::from_seed(12, 0);
    let n_cts = 8; // ≈ 32k parameters

    // single-key
    let t0 = Instant::now();
    let _ = ctx.keygen(&mut rng);
    let single_keygen = t0.elapsed().as_secs_f64();
    let single = measure_pipeline(&ctx, 2, (n_cts * ctx.batch()) as u64, n_cts, &mut rng);

    // threshold (2-party)
    let th = measure_threshold(&ctx, 2, n_cts, &mut rng);

    let mut t = Table::new(
        "Fig. 12 — Threshold-HE vs Single-Key FedAvg (2 parties, 8 ciphertexts)",
        &["Stage", "Single-Key", "Threshold (2-party)", "Threshold/Single"],
    );
    let rows = [
        ("KeyGen", single_keygen, th.keygen_secs),
        ("Encrypt (all parties)", single.encrypt_secs * 2.0, th.encrypt_secs),
        ("Aggregate", single.aggregate_secs, th.aggregate_secs),
        ("Decrypt", single.decrypt_secs, th.decrypt_secs),
    ];
    for (name, s, thv) in rows {
        t.row(vec![
            name.to_string(),
            human_secs(s),
            human_secs(thv),
            format!("{:.2}x", thv / s.max(1e-12)),
        ]);
    }
    t.print();
    println!("\nShape check: encryption/aggregation match the single-key variant; keygen and");
    println!("decryption pay the interactive overhead (partial decryptions + combination),");
    println!("as in the paper's Fig. 12.");
}
