//! Fig. 9 reproduction: DLG gradient-inversion defense on LeNet —
//! top-s sensitive masking (left panel) vs random masking (right panel).
//! Each configuration runs multiple restarts and reports the best recovery.

use fedml_he::attacks::dlg::{run_dlg, DlgConfig};
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::fl::data::synthetic_images;
use fedml_he::he_agg::EncryptionMask;
use fedml_he::runtime::executor::{Arg, Runtime};
use fedml_he::util::table::Table;

fn main() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("fig9: artifacts not built (run `make artifacts`)");
        return;
    }
    let rt = Runtime::new(dir).unwrap();
    let model = "lenet";
    let params = rt.manifest.load_init_params(model).unwrap();
    let d = synthetic_images(0, 8, (1, 28, 28), 10, 0.9, 19);
    let (x1, y1) = d.batch(0, 1);
    // victim gradient (single image replicated to the fixed batch)
    let b = rt.manifest.train_batch;
    let (xb, yb) = {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..b {
            xs.extend_from_slice(&x1);
            ys.extend_from_slice(&y1);
        }
        (xs, ys)
    };
    let grad = rt
        .execute(
            "lenet_grad",
            &[
                Arg::F32(&params, vec![params.len() as i64]),
                Arg::F32(&xb, vec![b as i64, 1, 28, 28]),
                Arg::I32(&yb, vec![b as i64]),
            ],
        )
        .unwrap()[0]
        .to_vec::<f32>()
        .unwrap();
    let k = rt.manifest.sens_batch;
    let (sx, sy) = d.batch(0, k);
    let sens = rt
        .execute(
            "lenet_sens",
            &[
                Arg::F32(&params, vec![params.len() as i64]),
                Arg::F32(&sx, vec![k as i64, 1, 28, 28]),
                Arg::I32(&sy, vec![k as i64]),
            ],
        )
        .unwrap()[0]
        .to_vec::<f32>()
        .unwrap();

    let cfg = DlgConfig {
        iters: 100,
        restarts: 3,
        lr: 0.05,
    };
    let mut t = Table::new(
        "Fig. 9 — DLG on LeNet: recovery quality vs protection (higher SSIM = worse privacy)",
        &["Mask", "Ratio", "MSE", "PSNR (dB)", "SSIM"],
    );
    let total = params.len();
    let cases: Vec<(String, EncryptionMask)> = vec![
        ("none".into(), EncryptionMask::empty(total)),
        ("top-s 5%".into(), EncryptionMask::top_p(&sens, 0.05)),
        ("top-s 10%".into(), EncryptionMask::top_p(&sens, 0.10)),
        ("top-s 30%".into(), EncryptionMask::top_p(&sens, 0.30)),
        (
            "random 10%".into(),
            EncryptionMask::random(total, 0.10, &mut ChaChaRng::from_seed(1, 1)),
        ),
        (
            "random 42.5%".into(),
            EncryptionMask::random(total, 0.425, &mut ChaChaRng::from_seed(1, 2)),
        ),
        (
            "random 70%".into(),
            EncryptionMask::random(total, 0.70, &mut ChaChaRng::from_seed(1, 3)),
        ),
    ];
    for (name, mask) in cases {
        let mut rng = ChaChaRng::from_seed(9, 0);
        let out = run_dlg(&rt, model, &params, &x1, &grad, &mask, &cfg, &mut rng).unwrap();
        t.row(vec![
            name,
            format!("{:.1}%", 100.0 * mask.ratio()),
            format!("{:.4}", out.similarity.mse),
            format!("{:.2}", out.similarity.psnr),
            format!("{:.4}", out.similarity.ssim),
        ]);
    }
    t.print();
    println!("\nShape check: top-10% sensitive masking should defend at least as well as");
    println!("random masking at ~42.5% — the paper's Fig. 9 crossover.");
}
