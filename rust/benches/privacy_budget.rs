//! §3 Remarks 3.12–3.14 reproduction: privacy budgets of full-DP, random
//! selection and sensitivity selection — analytic U(0,1) forms plus the
//! empirical budget on a real measured LeNet sensitivity map.

use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::he_agg::EncryptionMask;
use fedml_he::privacy::budget::{budget_full_dp, budget_with_mask, expected_budgets};
use fedml_he::util::table::Table;

fn main() {
    let n = 100_000usize;
    let b = 1.0;
    let mut rng = ChaChaRng::from_seed(3, 0);
    let sens: Vec<f32> = (0..n).map(|_| rng.uniform_f64() as f32).collect();
    let j = budget_full_dp(&sens, b);

    let mut t = Table::new(
        "Remarks 3.12-3.14 — privacy budget (Δf ~ U(0,1), n = 100k, b = 1)",
        &["p", "J (full DP)", "random (1-p)J", "selective (1-p)^2 J", "empirical selective"],
    );
    for p in [0.1, 0.3, 0.5, 0.7, 0.9] {
        let (ja, ra, sa) = expected_budgets(n, p, b);
        let emp = budget_with_mask(&sens, &EncryptionMask::top_p(&sens, p), b);
        t.row(vec![
            format!("{p:.1}"),
            format!("{ja:.0}"),
            format!("{ra:.0}"),
            format!("{sa:.0}"),
            format!("{emp:.0}"),
        ]);
    }
    t.print();
    println!("\nJ measured: {j:.0}; key observation: selective needs (1-p)x less budget");
    println!("than random at the same ratio (Remark 3.14).");

    // empirical budget on a real sensitivity map if artifacts are present
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        use fedml_he::runtime::executor::{Arg, Runtime};
        let rt = Runtime::new(dir).unwrap();
        let params = rt.manifest.load_init_params("lenet").unwrap();
        let d = fedml_he::fl::data::synthetic_images(0, 8, (1, 28, 28), 10, 0.5, 5);
        let k = rt.manifest.sens_batch;
        let (x, y) = d.batch(0, k);
        let s = rt
            .execute(
                "lenet_sens",
                &[
                    Arg::F32(&params, vec![params.len() as i64]),
                    Arg::F32(&x, vec![k as i64, 1, 28, 28]),
                    Arg::I32(&y, vec![k as i64]),
                ],
            )
            .unwrap()[0]
            .to_vec::<f32>()
            .unwrap();
        let jl = budget_full_dp(&s, b);
        let sel = budget_with_mask(&s, &EncryptionMask::top_p(&s, 0.3), b);
        let mut rng = ChaChaRng::from_seed(4, 0);
        let rnd = budget_with_mask(&s, &EncryptionMask::random(s.len(), 0.3, &mut rng), b);
        println!("\nMeasured LeNet map: J = {jl:.3}; random-30% = {rnd:.3}; selective-30% = {sel:.3}");
        println!(
            "selective/random budget ratio = {:.3} (real maps are heavier-tailed than U(0,1), so the gain exceeds (1-p))",
            sel / rnd
        );
    }
}
