//! Fig. 8 reproduction: time distribution of one ResNet-50 training cycle at
//! a single-AWS-region bandwidth of 200 MB/s — plaintext FL vs HE without
//! optimization vs HE with optimization (DoubleSqueeze k=1,000,000 + 30%
//! selective encryption, the paper's setup).
//!
//! Local-training time is modeled from our measured per-parameter f32 SGD
//! cost scaled to ResNet-50's parameter count (the paper's absolute GPU
//! train time is testbed-specific; the reproduction target is the *relative
//! composition* of the cycle).

use fedml_he::bench_support::{measure_selective, time_iters};
use fedml_he::ckks::CkksContext;
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::fl::model_meta::{lookup, plaintext_bytes};
use fedml_he::netsim::FIG8_REGION;
use fedml_he::util::{human_secs, table::Table};

fn main() {
    let ctx = CkksContext::default_paper().unwrap();
    let mut rng = ChaChaRng::from_seed(8, 0);
    let m = lookup("resnet50").unwrap();
    let bw = FIG8_REGION;

    // local training cost model: measured f32 MAC throughput × a 3-local-
    // epoch ResNet-50 step budget (≈ 20 flops/param/sample × 128 samples)
    let probe: Vec<f32> = (0..1 << 20).map(|i| i as f32 * 1e-6).collect();
    let mut acc = 0.0f32;
    let per_mac = time_iters(4, || {
        acc = probe.iter().fold(acc, |a, &x| a + x * 1.000001);
    }) / (1 << 20) as f64;
    std::hint::black_box(acc);
    let train_secs = per_mac * m.params as f64 * 20.0 * 128.0;

    let pt_bytes = plaintext_bytes(m.params);
    // DoubleSqueeze k=1M then 30% mask over the compressed update
    let k = 1_000_000u64;
    let opt_cost = measure_selective(&ctx, 3, k, 0.30, 16, &mut rng);
    let full_cost = measure_selective(&ctx, 3, m.params, 1.0, 16, &mut rng);

    let rows = [
        (
            "Plaintext FL",
            train_secs,
            0.0,
            bw.transfer_secs(2 * pt_bytes),
        ),
        (
            "HE w/o optimization",
            train_secs,
            full_cost.he_secs(),
            bw.transfer_secs(2 * full_cost.ct_bytes),
        ),
        (
            "HE w/ optimization (DoubleSqueeze k=1M + 30% mask)",
            train_secs,
            opt_cost.he_secs() + opt_cost.plain_secs,
            bw.transfer_secs(2 * opt_cost.ct_bytes),
        ),
    ];
    let mut t = Table::new(
        "Fig. 8 — ResNet-50 training-cycle composition @ 200 MB/s",
        &["Setup", "Local Train", "HE Ops", "Comm", "Total", "Comm+HE %"],
    );
    for (name, tr, he, comm) in rows {
        let total = tr + he + comm;
        t.row(vec![
            name.to_string(),
            human_secs(tr),
            human_secs(he),
            human_secs(comm),
            human_secs(total),
            format!("{:.1}%", 100.0 * (he + comm) / total),
        ]);
    }
    t.print();
    println!("\nShape check: unoptimized HE shifts a large share of the cycle into");
    println!("aggregation-related steps; the optimized setup restores a near-plaintext profile.");
}
