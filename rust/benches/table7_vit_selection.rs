//! Table 7 reproduction: overheads at different selective-encryption ratios
//! on Vision Transformer (86M parameters), including the plaintext share.

use fedml_he::bench_support::measure_selective;
use fedml_he::ckks::CkksContext;
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::util::{human_bytes, human_secs, table::Table};

fn main() {
    let ctx = CkksContext::default_paper().unwrap();
    let mut rng = ChaChaRng::from_seed(77, 0);
    let m = fedml_he::fl::model_meta::lookup("vit").unwrap();
    let base = measure_selective(&ctx, 3, m.params, 0.0, 16, &mut rng);
    let base_time = base.he_secs() + base.plain_secs;
    let mut t = Table::new(
        "Table 7 — Selection-ratio overheads on Vision Transformer (86M, 3 clients)",
        &["Selection", "Comp (s)", "Comm", "Comp Ratio", "Comm Ratio"],
    );
    for r in [0.0, 0.1, 0.3, 0.5, 0.7, 1.0] {
        let c = measure_selective(&ctx, 3, m.params, r, 16, &mut rng);
        let time = c.he_secs() + c.plain_secs;
        let label = if r == 1.0 {
            "Enc w/ All".to_string()
        } else {
            format!("Enc w/ {:.0}%", r * 100.0)
        };
        t.row(vec![
            label,
            human_secs(time),
            human_bytes(c.ct_bytes),
            format!("{:.2}", time / base_time),
            format!("{:.2}", c.ct_bytes as f64 / base.ct_bytes as f64),
        ]);
    }
    t.print();
    println!("\nShape check: both ratios grow ~linearly in the encrypted fraction,");
    println!("reaching ~16x comm expansion at full encryption (paper: 16.62x).");
}
