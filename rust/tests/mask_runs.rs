//! Property tests for the run-based mask layout: every run-space operation
//! (construction, complement, union/merge, wire roundtrip) must be
//! semantically equivalent to a dense boolean reference, across adversarial
//! run patterns — singletons, full-range, alternating, clustered blocks.
//! Plus the acceptance regression: a layer-granularity BERT-sized mask
//! serializes in O(runs) bytes (< 16 KB), not the seed's ~44 MB index list.

use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::fl::model_meta;
use fedml_he::he_agg::{EncryptionMask, MaskLayout, Run};

/// Dense boolean reference model of a coordinate set.
#[derive(Clone, PartialEq, Debug)]
struct Dense(Vec<bool>);

impl Dense {
    fn from_layout(l: &MaskLayout) -> Dense {
        Dense(l.to_dense())
    }

    fn count(&self) -> usize {
        self.0.iter().filter(|&&b| b).count()
    }

    fn complement(&self) -> Dense {
        Dense(self.0.iter().map(|&b| !b).collect())
    }

    fn union(&self, other: &Dense) -> Dense {
        Dense(self.0.iter().zip(other.0.iter()).map(|(&a, &b)| a || b).collect())
    }

    /// Minimal run count of the dense set (for the coalescing invariant).
    fn n_runs(&self) -> usize {
        let mut runs = 0;
        let mut prev = false;
        for &b in &self.0 {
            if b && !prev {
                runs += 1;
            }
            prev = b;
        }
        runs
    }
}

/// Adversarial pattern generators over a `total`-sized space.
fn patterns(total: usize, rng: &mut ChaChaRng) -> Vec<Vec<Run>> {
    let mut out: Vec<Vec<Run>> = vec![
        Vec::new(),                          // empty
        vec![Run { lo: 0, hi: total }],      // full-range
        // alternating singletons
        (0..total).step_by(2).map(|i| Run { lo: i, hi: i + 1 }).collect(),
        // first + last singleton
        vec![Run { lo: 0, hi: 1 }, Run { lo: total - 1, hi: total }],
        // adjacent runs that must coalesce
        vec![Run { lo: 3, hi: 10 }, Run { lo: 10, hi: 20 }, Run { lo: 20, hi: 21 }],
        // overlapping runs
        vec![Run { lo: 5, hi: 30 }, Run { lo: 10, hi: 25 }, Run { lo: 28, hi: 40 }],
    ];
    // random clustered blocks
    for _ in 0..8 {
        let n_blocks = 1 + rng.uniform_usize(12);
        let mut runs = Vec::with_capacity(n_blocks);
        for _ in 0..n_blocks {
            let lo = rng.uniform_usize(total);
            let len = 1 + rng.uniform_usize(total / 4 + 1);
            runs.push(Run { lo, hi: (lo + len).min(total) });
        }
        out.push(runs);
    }
    // random index soup (stress from_sorted_indices agreement)
    for _ in 0..4 {
        let k = rng.uniform_usize(total);
        let mut idx: Vec<u32> = (0..total as u32).collect();
        rng.shuffle(&mut idx);
        let mut picked = idx[..k].to_vec();
        picked.sort_unstable();
        out.push(picked.iter().map(|&i| Run { lo: i as usize, hi: i as usize + 1 }).collect());
    }
    out
}

#[test]
fn construction_matches_dense_reference() {
    let mut rng = ChaChaRng::from_seed(2024, 0);
    for total in [1usize, 2, 64, 257, 1000] {
        for runs in patterns(total, &mut rng) {
            let layout = MaskLayout::from_runs(total, runs.clone());
            // dense reference built independently, with clamping
            let mut dense = vec![false; total];
            for r in &runs {
                for d in dense.iter_mut().take(r.hi.min(total)).skip(r.lo.min(total)) {
                    *d = true;
                }
            }
            let reference = Dense(dense);
            assert_eq!(Dense::from_layout(&layout), reference);
            assert_eq!(layout.count(), reference.count());
            // runs are coalesced to the minimal representation
            assert_eq!(layout.n_runs(), reference.n_runs());
            // contains() agrees pointwise
            for i in 0..total {
                assert_eq!(layout.contains(i), reference.0[i], "i={i}");
            }
            // iter_indices agrees with the dense set
            let got: Vec<usize> = layout.iter_indices().collect();
            let want: Vec<usize> =
                (0..total).filter(|&i| reference.0[i]).collect();
            assert_eq!(got, want);
        }
    }
}

#[test]
fn from_indices_equals_from_runs() {
    let mut rng = ChaChaRng::from_seed(77, 0);
    for total in [10usize, 100, 999] {
        for runs in patterns(total, &mut rng) {
            let a = MaskLayout::from_runs(total, runs);
            let idx: Vec<u32> = a.iter_indices().map(|i| i as u32).collect();
            let b = MaskLayout::from_sorted_indices(total, &idx);
            assert_eq!(a, b);
        }
    }
}

#[test]
fn complement_matches_dense_reference() {
    let mut rng = ChaChaRng::from_seed(31, 0);
    for total in [1usize, 17, 512] {
        for runs in patterns(total, &mut rng) {
            let layout = MaskLayout::from_runs(total, runs);
            let comp = layout.complement();
            assert_eq!(
                Dense::from_layout(&comp),
                Dense::from_layout(&layout).complement()
            );
            assert_eq!(comp.count() + layout.count(), total);
            // involution
            assert_eq!(comp.complement(), layout);
        }
    }
}

#[test]
fn union_matches_dense_reference() {
    let mut rng = ChaChaRng::from_seed(55, 0);
    for total in [8usize, 100, 400] {
        let ps = patterns(total, &mut rng);
        for pair in ps.windows(2) {
            let a = MaskLayout::from_runs(total, pair[0].clone());
            let b = MaskLayout::from_runs(total, pair[1].clone());
            let u = a.union(&b);
            assert_eq!(
                Dense::from_layout(&u),
                Dense::from_layout(&a).union(&Dense::from_layout(&b))
            );
            // union is commutative and idempotent
            assert_eq!(u, b.union(&a));
            assert_eq!(u.union(&a), u);
        }
    }
}

#[test]
fn wire_roundtrip_across_patterns() {
    let mut rng = ChaChaRng::from_seed(91, 0);
    for total in [1usize, 63, 1024] {
        for runs in patterns(total, &mut rng) {
            let layout = MaskLayout::from_runs(total, runs);
            let bytes = layout.to_bytes();
            let back = MaskLayout::from_bytes(&bytes).unwrap();
            assert_eq!(back, layout);
            // wire cost is O(runs): ≤ 12-byte header + 20 B/run (2 varints)
            assert!(bytes.len() <= 12 + 20 * layout.n_runs().max(1));
        }
    }
}

#[test]
fn malformed_bytes_rejected() {
    let layout = MaskLayout::from_runs(
        1000,
        vec![Run { lo: 3, hi: 40 }, Run { lo: 100, hi: 900 }],
    );
    let good = layout.to_bytes();
    assert!(MaskLayout::from_bytes(&good).is_ok());
    // every strict prefix fails (truncation at any point)
    for cut in 0..good.len() {
        assert!(MaskLayout::from_bytes(&good[..cut]).is_err(), "cut={cut}");
    }
    // trailing garbage fails
    let mut long = good.clone();
    long.extend_from_slice(&[1, 1]);
    assert!(MaskLayout::from_bytes(&long).is_err());
    // declared run count beyond payload fails
    let mut over = good.clone();
    over[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(MaskLayout::from_bytes(&over).is_err());
    // unbounded varint (ten 0x80 continuation bytes) fails
    let mut runaway = Vec::new();
    runaway.extend_from_slice(&1000u64.to_le_bytes());
    runaway.extend_from_slice(&1u32.to_le_bytes());
    runaway.extend_from_slice(&[0x80; 12]);
    assert!(MaskLayout::from_bytes(&runaway).is_err());
}

/// The acceptance regression (ISSUE 2): a layer-granularity mask over a
/// BERT-sized parameter space (~200 layers, 100M+ params, p = 0.1)
/// serializes in < 16 KB under the run-delta format, where the seed's
/// 4 B/index list format needed ~44 MB.
#[test]
fn bert_layer_mask_wire_is_o_runs_not_o_params() {
    let bert = model_meta::lookup("bert").unwrap();
    assert!(bert.params > 100_000_000);
    let spans = bert.layer_spans();
    assert!(spans.len() >= 190, "{} layers", spans.len());
    // synthetic per-layer scores (any values — cost depends on run count)
    let scores: Vec<f32> = (0..spans.len()).map(|i| ((i * 37) % 101) as f32).collect();
    let mask =
        EncryptionMask::from_layer_scores(bert.params as usize, &scores, &spans, 0.1);
    // at least p of the space is covered by whole layers
    assert!(mask.encrypted_count() >= (bert.params as f64 * 0.1) as usize);
    let bytes = mask.to_bytes();
    assert!(
        bytes.len() < 16 * 1024,
        "run-delta mask wire is {} bytes",
        bytes.len()
    );
    // the seed index-list format at the same coverage: 8 + 4k ≈ 44 MB
    let seed_format_bytes = 8 + 4 * mask.encrypted_count();
    assert!(seed_format_bytes > 40_000_000);
    // and the run format round-trips
    assert_eq!(EncryptionMask::from_bytes(&bytes).unwrap(), mask);
}

/// Selective-codec equivalence on adversarial run patterns: encrypting under
/// a run mask and decrypting recovers the vector, with the plaintext part
/// bit-exact — the run gather/scatter semantics match the dense split.
#[test]
fn codec_roundtrip_on_adversarial_patterns() {
    use fedml_he::ckks::CkksContext;
    use fedml_he::he_agg::SelectiveCodec;
    let ctx = CkksContext::new(256, 4, 40).unwrap();
    let codec = SelectiveCodec::new(ctx);
    let mut rng = ChaChaRng::from_seed(123, 0);
    let (pk, sk) = codec.ctx.keygen(&mut rng);
    let total = 700;
    let params: Vec<f32> = (0..total).map(|i| (i as f32 * 0.013).sin()).collect();
    let mut pat_rng = ChaChaRng::from_seed(321, 0);
    for runs in patterns(total, &mut pat_rng) {
        let mask = EncryptionMask::from_runs(total, runs);
        let upd = codec.encrypt_update(&params, &mask, &pk, &mut rng);
        assert_eq!(upd.plain.len(), total - mask.encrypted_count());
        let back = codec.decrypt_update(&upd, &mask, &sk);
        let dense = mask.to_dense();
        for i in 0..total {
            if dense[i] {
                assert!((back[i] - params[i]).abs() < 1e-4, "i={i}");
            } else {
                assert_eq!(back[i], params[i], "plaintext i={i} must be bit-exact");
            }
        }
    }
}
