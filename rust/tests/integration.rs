//! Integration tests over the public `fedml_he` API: the full three-layer
//! stack exercised the way a downstream user would (`make artifacts` must
//! have been run; tests skip gracefully if not).

use fedml_he::ckks::CkksContext;
use fedml_he::coordinator::{Backend, FlConfig, FlServer, KeyMode, Selection};
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::he_agg::{native, EncryptionMask, SelectiveCodec};
use fedml_he::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new(dir).unwrap())
}

/// Exact-aggregation claim (Table 1): an HE federated run and a plaintext
/// run with identical seeds produce the same model to CKKS precision, and
/// selective (p=0.1) sits in between with the plaintext part bit-exact.
#[test]
fn he_fl_is_exact_aggregation() {
    let Some(rt) = runtime() else { return };
    let base = FlConfig {
        model: "mlp".into(),
        clients: 4,
        rounds: 2,
        local_steps: 2,
        samples_per_client: 64,
        eval_every: 0,
        dropout: 0.0,
        ..Default::default()
    };
    let run = |sel: Selection, backend: Backend| {
        let mut cfg = base.clone();
        cfg.selection = sel;
        cfg.backend = backend;
        FlServer::new(&rt, cfg).unwrap().run().unwrap().1
    };
    let plain = run(Selection::None, Backend::Native);
    let full_xla = run(Selection::Full, Backend::Xla);
    let full_native = run(Selection::Full, Backend::Native);
    let selective = run(Selection::TopP, Backend::Xla);

    let max_err = |a: &[f32], b: &[f32]| {
        a.iter()
            .zip(b.iter())
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max)
    };
    assert!(max_err(&plain, &full_xla) < 1e-3, "HE != plaintext result");
    assert!(max_err(&plain, &selective) < 1e-3, "selective != plaintext");
    // the two backends must agree with each other even more tightly
    assert!(max_err(&full_xla, &full_native) < 1e-4, "backends diverge");
}

/// Dropout robustness (Table 1): with 40% dropout the run completes and
/// still learns.
#[test]
fn dropout_robustness() {
    let Some(rt) = runtime() else { return };
    let cfg = FlConfig {
        model: "mlp".into(),
        clients: 5,
        rounds: 6,
        local_steps: 2,
        dropout: 0.4,
        selection: Selection::TopP,
        ratio: 0.2,
        samples_per_client: 64,
        eval_every: 6,
        ..Default::default()
    };
    let (report, _) = FlServer::new(&rt, cfg).unwrap().run().unwrap();
    assert_eq!(report.rounds.len(), 6);
    assert!(report.rounds.iter().any(|r| r.participants < 5));
    let first = report.rounds.first().unwrap().train_loss;
    let last = report.rounds.last().unwrap().train_loss;
    assert!(last < first, "no learning under dropout: {first} -> {last}");
}

/// Threshold mode through the full coordinator (Appendix B).
#[test]
fn threshold_end_to_end() {
    let Some(rt) = runtime() else { return };
    let cfg = FlConfig {
        model: "mlp".into(),
        clients: 3,
        rounds: 2,
        local_steps: 1,
        key_mode: KeyMode::Threshold,
        // the seed wire needs a single decryption key; pin dense so the
        // CI-wide FEDML_HE_CT_WIRE=seed rerun can't poison threshold mode
        ct_wire: fedml_he::ckks::CtWire::Dense,
        backend: Backend::Native,
        selection: Selection::Random,
        ratio: 0.15,
        samples_per_client: 64,
        eval_every: 0,
        ..Default::default()
    };
    let (report, global) = FlServer::new(&rt, cfg).unwrap().run().unwrap();
    assert_eq!(report.rounds.len(), 2);
    assert!(global.iter().all(|v| v.is_finite()));
}

/// Wire-format interop: an update serialized ciphertext-by-ciphertext
/// round-trips and aggregates identically.
#[test]
fn serialization_interop() {
    let ctx = CkksContext::new(1024, 4, 45).unwrap();
    let codec = SelectiveCodec::new(ctx);
    let mut rng = ChaChaRng::from_seed(42, 0);
    let (pk, sk) = codec.ctx.keygen(&mut rng);
    let params: Vec<f32> = (0..2000).map(|i| (i as f32 * 0.01).sin()).collect();
    let mask = EncryptionMask::full(2000);
    let updates: Vec<_> = (0..3)
        .map(|_| {
            let mut u = codec.encrypt_update(&params, &mask, &pk, &mut rng);
            // serialize + deserialize every ciphertext (the network path)
            u.cts = u
                .cts
                .iter()
                .map(|ct| {
                    let bytes = fedml_he::ckks::serialize::ciphertext_to_bytes(ct);
                    fedml_he::ckks::serialize::ciphertext_from_bytes(&bytes, &codec.ctx.params)
                        .unwrap()
                })
                .collect();
            u
        })
        .collect();
    let agg = native::aggregate(&updates, &[0.5, 0.25, 0.25], &codec.ctx.params);
    let out = codec.decrypt_update(&agg, &mask, &sk);
    for (a, b) in params.iter().zip(out.iter()) {
        assert!((a - b).abs() < 1e-5);
    }
}

/// DP composition on the plaintext part: Algorithm 1's optional noise
/// perturbs only unencrypted coordinates.
#[test]
fn dp_noise_on_plaintext_part_only() {
    let Some(rt) = runtime() else { return };
    let cfg = FlConfig {
        model: "mlp".into(),
        clients: 2,
        rounds: 1,
        local_steps: 1,
        dp_scale: Some(0.5),
        selection: Selection::Random,
        ratio: 0.5,
        samples_per_client: 64,
        eval_every: 0,
        backend: Backend::Native,
        ..Default::default()
    };
    let (report, global) = FlServer::new(&rt, cfg).unwrap().run().unwrap();
    assert_eq!(report.rounds.len(), 1);
    // noisy but finite
    assert!(global.iter().all(|v| v.is_finite()));
    let spread = global.iter().map(|v| v.abs()).sum::<f32>() / global.len() as f32;
    assert!(spread > 0.05, "DP noise should be visible (spread {spread})");
}

/// The paper's privacy-map pipeline through the public API: sensitivity →
/// secure aggregation → top-p mask captures most of the sensitivity mass.
#[test]
fn privacy_map_pipeline() {
    let Some(rt) = runtime() else { return };
    let mut trainer = fedml_he::fl::LocalTrainer::new(&rt, "lenet").unwrap();
    let data = fedml_he::fl::Workload::Image(fedml_he::fl::data::synthetic_images(
        0,
        64,
        (1, 28, 28),
        10,
        0.5,
        3,
    ));
    let params = rt.manifest.load_init_params("lenet").unwrap();
    let s = trainer.sensitivity(&params, &data).unwrap();
    let mask = EncryptionMask::top_p(&s, 0.1);
    let captured: f64 = mask
        .runs()
        .iter()
        .flat_map(|r| s[r.lo..r.hi].iter())
        .map(|&v| v as f64)
        .sum();
    let total: f64 = s.iter().map(|&v| v as f64).sum();
    assert!(
        captured / total > 0.3,
        "top-10% should capture >30% of mass, got {:.2}",
        captured / total
    );
    // budget ordering: selective < random at the same ratio
    let mut rng = ChaChaRng::from_seed(1, 0);
    let sel = fedml_he::privacy::budget::budget_with_mask(&s, &mask, 1.0);
    let rnd = fedml_he::privacy::budget::budget_with_mask(
        &s,
        &EncryptionMask::random(s.len(), 0.1, &mut rng),
        1.0,
    );
    assert!(sel < rnd, "selective {sel} !< random {rnd}");
}
