//! Acceptance gates for the round-phase state machine and the persistent
//! duplex session transport (DESIGN.md §9). Everything here runs on the
//! artifact-free synthetic workload, so these are tier-1 tests on any
//! machine:
//!
//! * a full multi-round `--transport tcp` run (client session threads over
//!   loopback: real mask/global downlink frames, client-side decryption)
//!   produces a final model **bitwise identical** to the same-seed
//!   `--transport sim` run;
//! * sim and tcp reports label their timing sources distinctly, and tcp
//!   rounds report measured (non-simulated) downlink bytes;
//! * a client that disconnects between rounds rejoins its persistent slot
//!   and the next round completes with it.

use fedml_he::coordinator::{FlConfig, FlServer, Selection, Transport};
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::he_agg::{native, EncryptionMask, SelectiveCodec};
use fedml_he::transport::{
    ChaosConfig, ClientSession, DownBegin, IntakeConfig, SessionHub, SessionOpts, UpdateShape,
};
use std::sync::mpsc;
use std::time::Duration;

/// Deterministic per-(client, round) model for the hub-level tests — a
/// plain fn so spawned client threads can call it without borrows.
fn client_model(total: usize, client: u64, round: u64) -> Vec<f32> {
    (0..total)
        .map(|i| ((i as u64 + 131 * client + 7 * round) as f32 * 0.003).sin())
        .collect()
}

fn synthetic_cfg() -> FlConfig {
    FlConfig {
        model: "synthetic".into(),
        synthetic_dim: 2048,
        clients: 3,
        rounds: 3,
        local_steps: 2,
        lr: 0.2,
        ratio: 0.1,
        selection: Selection::TopP,
        dropout: 0.0,
        eval_every: 3,
        seed: 17,
        engine: fedml_he::agg_engine::Engine::Pipeline,
        shards: 2,
        ..Default::default()
    }
}

#[test]
fn synthetic_sim_run_trains_and_reports() {
    let (report, global) = FlServer::standalone(synthetic_cfg()).unwrap().run().unwrap();
    assert_eq!(report.rounds.len(), 3);
    assert_eq!(global.len(), 2048);
    assert!(global.iter().all(|v| v.is_finite()));
    assert_eq!(report.timing_source, "simulated");
    assert!(report.rounds.iter().all(|r| r.timing_source == "simulated"));
    assert!((report.mask_ratio - 0.1).abs() < 0.01);
    assert!(report.mask_bytes > 0 && report.mask_upload_bytes > 0);
    assert!(!report.evals.is_empty());
    // the synthetic objective is a contraction: losses trend down
    let first = report.rounds.first().unwrap().train_loss;
    let last = report.rounds.last().unwrap().train_loss;
    assert!(last < first, "loss {first} -> {last}");
    // the final aggregate is broadcast in the finale (sim accounting)
    assert!(report.fin_downlink_bytes > 0);
}

#[test]
fn tcp_run_bitwise_matches_sim_run_and_labels_timing() {
    // The acceptance criterion of ISSUE 5, at thread scale: the same phase
    // machine over persistent loopback sessions (mask + aggregate as real
    // downlink frames, per-round uploads over one connection per client,
    // client-side decryption) must produce a bitwise-identical final model
    // to the in-process simulator for the same seed.
    let sim_cfg = synthetic_cfg();
    let mut tcp_cfg = synthetic_cfg();
    tcp_cfg.transport = Transport::Tcp;
    let (ra, ga) = FlServer::standalone(sim_cfg).unwrap().run().unwrap();
    let (rb, gb) = FlServer::standalone(tcp_cfg).unwrap().run().unwrap();
    assert_eq!(ga.len(), gb.len());
    for (i, (a, b)) in ga.iter().zip(gb.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "param {i}: {a} != {b}");
    }
    // regression (ISSUE 5 satellite): sim and tcp reports must label their
    // timing sources distinctly — no more simulated broadcast charged to a
    // tcp run
    assert_eq!(ra.timing_source, "simulated");
    assert_eq!(rb.timing_source, "measured");
    assert!(rb.rounds.iter().all(|r| r.timing_source == "measured"));
    // real downlink frames: measured bytes on the mask broadcast, on every
    // aggregate-carrying round, and on the FIN downlink
    assert!(rb.mask_downlink_bytes > 0);
    assert_eq!(ra.mask_downlink_bytes, 0);
    assert!(rb.rounds[1].download_bytes > 0);
    assert!(rb.rounds[1].downlink_secs >= 0.0);
    assert!(rb.fin_downlink_bytes > 0);
    // uplink is real too
    assert!(rb.rounds.iter().all(|r| r.upload_bytes > 0));
    assert!(rb.rounds.iter().all(|r| r.stragglers_dropped == 0));
    // client-reported metrics made it across the wire
    assert!(rb.rounds.iter().all(|r| r.train_loss > 0.0));
    // both runs evaluated the same pure synthetic objective
    assert_eq!(ra.evals.len(), rb.evals.len());
    for (a, b) in ra.evals.iter().zip(rb.evals.iter()) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    }
}

#[test]
fn seed_wire_run_bitwise_matches_across_transports_and_shrinks_uplink() {
    // The seed-expanded ciphertext wire (`--ct-wire seed`) acceptance gate
    // at thread scale: sim, tcp/threads, and tcp/hub runs of the same task
    // must produce bitwise-identical final models while clients upload
    // symmetric seeded ciphertexts (32-byte a-part seeds, lazily expanded
    // server-side).
    use fedml_he::ckks::CtWire;
    use fedml_he::coordinator::TransportBackend;
    let mut sim_cfg = synthetic_cfg();
    sim_cfg.ct_wire = CtWire::Seed;
    let mut tcp_cfg = sim_cfg.clone();
    tcp_cfg.transport = Transport::Tcp;
    let mut hub_cfg = tcp_cfg.clone();
    hub_cfg.transport_backend = TransportBackend::Hub;
    let (rs, gs) = FlServer::standalone(sim_cfg).unwrap().run().unwrap();
    let (rt, gt) = FlServer::standalone(tcp_cfg).unwrap().run().unwrap();
    let (rh, gh) = FlServer::standalone(hub_cfg).unwrap().run().unwrap();
    assert_eq!(gs.len(), gt.len());
    for (i, (a, b)) in gs.iter().zip(gt.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "sim/tcp param {i}: {a} != {b}");
    }
    for (i, (a, b)) in gs.iter().zip(gh.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "sim/hub param {i}: {a} != {b}");
    }
    assert!(rt.rounds.iter().all(|r| r.upload_bytes > 0));
    assert!(rh.rounds.iter().all(|r| r.upload_bytes > 0));

    // and the wire actually shrank: the same task on the dense wire uploads
    // strictly more bytes per round (sim accounting covers both modes; pin
    // Dense explicitly so the CI-wide FEDML_HE_CT_WIRE=seed rerun can't
    // collapse both sides of the comparison)
    let mut dense_cfg = synthetic_cfg();
    dense_cfg.ct_wire = CtWire::Dense;
    let (rd, _) = FlServer::standalone(dense_cfg).unwrap().run().unwrap();
    for (seeded, dense) in rs.rounds.iter().zip(rd.rounds.iter()) {
        assert!(
            seeded.upload_bytes < dense.upload_bytes,
            "seed wire did not shrink the uplink: {} vs {}",
            seeded.upload_bytes,
            dense.upload_bytes
        );
    }
}

#[test]
fn tcp_run_with_dropout_completes() {
    // Non-participating clients still receive every downlink (they need
    // the next global) and the run completes — the HE dropout-robustness
    // claim over the real transport.
    let mut cfg = synthetic_cfg();
    cfg.transport = Transport::Tcp;
    cfg.clients = 4;
    cfg.rounds = 4;
    cfg.dropout = 0.4;
    cfg.seed = 23;
    cfg.eval_every = 0;
    let (report, global) = FlServer::standalone(cfg).unwrap().run().unwrap();
    assert_eq!(report.rounds.len(), 4);
    assert!(global.iter().all(|v| v.is_finite()));
    assert!(
        report.rounds.iter().any(|r| r.participants < 4),
        "dropout never struck in 4 rounds"
    );
    // a sim run with the same seed still agrees bitwise: dropout draws come
    // from the same server rng stream in both transports
    let mut sim = synthetic_cfg();
    sim.clients = 4;
    sim.rounds = 4;
    sim.dropout = 0.4;
    sim.seed = 23;
    sim.eval_every = 0;
    let (_, gs) = FlServer::standalone(sim).unwrap().run().unwrap();
    for (a, b) in gs.iter().zip(global.iter()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn injected_disconnect_is_bridged_by_the_rejoin_replay() {
    // ISSUE 8 satellite: a chaos-injected disconnect severs client 1 while
    // its round-0 END frame is on the wire, so the server fails its upload
    // AND the round-1 broadcast goes out against the dead socket. The
    // rejoining client must recover the whole round-1 downlink (mask +
    // DOWN_BEGIN + aggregate frames) purely from the handshake replay
    // cache, and round 1 must then seal bitwise identical to the oracle.
    let ctx = fedml_he::ckks::CkksContext::new(256, 3, 30).unwrap();
    let codec = SelectiveCodec::new(ctx.clone());
    let mut rng = ChaChaRng::from_seed(9, 0);
    let (pk, _sk) = codec.ctx.keygen(&mut rng);
    let total = 700usize;
    // full mask: the uplink is HELLO, BEGIN, n_cts CT chunks, END — which
    // pins the injected disconnect onto the END frame deterministically
    let mask = EncryptionMask::full(total);
    let shape = UpdateShape::for_round(&codec.ctx, &mask);
    let end_frame = (2 + shape.n_cts + 1) as u64;
    let mut hub = SessionHub::bind("127.0.0.1:0", ctx.params.clone(), 8).unwrap();
    let addr = hub.local_addr().unwrap().to_string();
    let opts = SessionOpts {
        connect_retry: Duration::from_secs(5),
        round_wait: Duration::from_secs(20),
        io_timeout: Duration::from_secs(5),
        ..SessionOpts::default()
    };
    let encrypt = |client: u64, round: u64| {
        let mut rng = ChaChaRng::from_seed(300 + client, round);
        codec.encrypt_update(&client_model(total, client, round), &mask, &pk, &mut rng)
    };
    let mask_bytes = mask.to_bytes();

    let (rejoin_tx, rejoin_rx) = mpsc::channel::<()>();
    let mut rejoin_rx = Some(rejoin_rx);
    let mut threads = Vec::new();
    for client in 0..2u64 {
        let addr = addr.clone();
        let params = ctx.params.clone();
        let mut opts = opts.clone();
        let codec = SelectiveCodec::new(ctx.clone());
        let pk = pk.clone();
        let mask = mask.clone();
        let rejoin_rx = if client == 1 { rejoin_rx.take() } else { None };
        if client == 1 {
            opts.chaos = Some(ChaosConfig {
                disconnect_at_frame: Some(end_frame),
                ..ChaosConfig::passthrough(0xBAD)
            });
        }
        threads.push(std::thread::spawn(move || {
            let (mut sess, _) =
                ClientSession::connect(&addr, client, params.clone(), opts.clone()).unwrap();
            sess.recv_mask(total).unwrap();
            let dl = sess.recv_round(0, Some(shape)).unwrap();
            assert!(dl.down.participate && !dl.down.has_agg);
            let mut rng = ChaChaRng::from_seed(300 + client, 0);
            let upd =
                codec.encrypt_update(&client_model(total, client, 0), &mask, &pk, &mut rng);
            let r0 = sess.upload(0, 0.5, &upd, None);
            if client == 1 {
                assert!(r0.is_err(), "the injected disconnect must fail the upload");
                // wait until the server has already broadcast round 1 into
                // the dead socket, then rejoin with a clean link
                rejoin_rx.unwrap().recv().unwrap();
                opts.chaos = None;
                let (s2, _) = ClientSession::connect(&addr, client, params, opts).unwrap();
                sess = s2;
                // the handshake replay carries the cached mask and the full
                // round-1 downlink; recv_round_any skips the mask replay
                let (round, dl) = sess.recv_round_any(Some(shape), total).unwrap();
                assert_eq!(round, 1, "replay must deliver the missed round");
                assert!(dl.down.has_agg && dl.agg.is_some());
            } else {
                r0.unwrap();
                let dl = sess.recv_round(1, Some(shape)).unwrap();
                assert!(dl.down.has_agg && dl.agg.is_some());
            }
            let mut rng = ChaChaRng::from_seed(300 + client, 1);
            let upd =
                codec.encrypt_update(&client_model(total, client, 1), &mask, &pk, &mut rng);
            sess.upload(1, 0.5, &upd, None).unwrap();
            let dl = sess.recv_round(2, Some(shape)).unwrap();
            assert!(dl.down.fin);
        }));
    }

    hub.wait_for_clients(2, Duration::from_secs(10)).unwrap();
    let out = hub.broadcast_mask(&[0, 1], &mask_bytes);
    assert!(out.failed.is_empty());
    let plan = |alpha: f64| DownBegin {
        alpha,
        alpha_mass: 0.0,
        n_cts: 0,
        n_plain: 0,
        total: 0,
        participate: true,
        has_agg: false,
        fin: false,
    };
    let out = hub.broadcast_round(0, &[(0, plan(0.5)), (1, plan(0.5))], None);
    assert!(out.failed.is_empty());
    hub.set_next_round(1);
    let outcome = hub.collect_round(
        &[(0, Some(0.5)), (1, Some(0.5))],
        shape,
        &IntakeConfig {
            round_id: 0,
            expected_uploads: 2,
            quorum: Some(1),
            straggler_timeout: Duration::from_secs(1),
            max_wait: Duration::from_secs(20),
            io_timeout: Duration::from_secs(2),
        },
    );
    // the severed upload is on the failure record, not silently absorbed
    assert_eq!(outcome.arrivals.len(), 1, "failed: {:?}", outcome.failed);
    assert_eq!(outcome.arrivals[0].client, 0);
    assert!(outcome.failed.contains(&1), "failed: {:?}", outcome.failed);

    // round 1 carries round 0's (client-0-only) aggregate; the push toward
    // client 1 hits the dead socket — the replay cache is what bridges it
    let agg0 = native::aggregate(&[encrypt(0, 0)], &[0.5], &codec.ctx.params);
    let round1 = DownBegin {
        alpha: 0.5,
        alpha_mass: 0.5,
        n_cts: agg0.cts.len(),
        n_plain: agg0.plain.len(),
        total: agg0.total,
        participate: true,
        has_agg: true,
        fin: false,
    };
    let _ = hub.broadcast_round(1, &[(0, round1), (1, round1)], Some(&agg0));
    hub.set_next_round(2);
    rejoin_tx.send(()).unwrap();
    let outcome = hub.collect_round(
        &[(0, Some(0.5)), (1, Some(0.5))],
        shape,
        &IntakeConfig {
            round_id: 1,
            expected_uploads: 2,
            quorum: None,
            straggler_timeout: Duration::from_secs(5),
            max_wait: Duration::from_secs(20),
            io_timeout: Duration::from_secs(5),
        },
    );
    assert_eq!(
        outcome.arrivals.len(),
        2,
        "round 1 after the replayed rejoin failed: {:?}",
        outcome.failed
    );
    // bitwise: the post-rejoin round matches the in-process oracle
    let oracle1 =
        native::aggregate(&[encrypt(0, 1), encrypt(1, 1)], &[0.5, 0.5], &codec.ctx.params);
    let mut arrivals = outcome.arrivals;
    arrivals.sort_by_key(|a| a.client);
    let agg1 = native::aggregate(
        &[(*arrivals[0].update).clone(), (*arrivals[1].update).clone()],
        &[0.5, 0.5],
        &codec.ctx.params,
    );
    assert_eq!(agg1.plain, oracle1.plain);
    for (a, b) in agg1.cts.iter().zip(oracle1.cts.iter()) {
        assert_eq!(a.c0, b.c0);
        assert_eq!(a.c1, b.c1);
    }
    let fin = DownBegin {
        alpha: 0.0,
        alpha_mass: 0.0,
        n_cts: 0,
        n_plain: 0,
        total: 0,
        participate: false,
        has_agg: false,
        fin: true,
    };
    let out = hub.broadcast_round(2, &[(0, fin), (1, fin)], None);
    assert!(out.failed.is_empty(), "post-rejoin fin failed: {:?}", out.failed);
    for t in threads {
        t.join().unwrap();
    }
    hub.shutdown();
}

#[test]
fn client_disconnects_between_rounds_and_rejoins_its_slot() {
    // Hub-level multi-round flow: client 1 completes round 0, loses its
    // connection, reconnects with the same id (rejoin), and round 1
    // completes with both clients — bitwise-identical aggregates to the
    // in-process oracle throughout.
    let ctx = fedml_he::ckks::CkksContext::new(256, 3, 30).unwrap();
    let codec = SelectiveCodec::new(ctx.clone());
    let mut rng = ChaChaRng::from_seed(5, 0);
    let (pk, _sk) = codec.ctx.keygen(&mut rng);
    let total = 700usize;
    let mask = EncryptionMask::full(total);
    let shape = UpdateShape::for_round(&codec.ctx, &mask);
    let mut hub = SessionHub::bind("127.0.0.1:0", ctx.params.clone(), 8).unwrap();
    let addr = hub.local_addr().unwrap().to_string();
    let opts = SessionOpts {
        connect_retry: Duration::from_secs(5),
        round_wait: Duration::from_secs(20),
        ..SessionOpts::default()
    };
    let icfg = |round: u64| IntakeConfig {
        round_id: round,
        expected_uploads: 2,
        quorum: None,
        straggler_timeout: Duration::from_secs(5),
        max_wait: Duration::from_secs(20),
        io_timeout: Duration::from_secs(5),
    };
    let encrypt = |client: u64, round: u64| {
        let mut rng = ChaChaRng::from_seed(100 + client, round);
        codec.encrypt_update(&client_model(total, client, round), &mask, &pk, &mut rng)
    };

    let (rejoined_tx, rejoined_rx) = mpsc::channel::<()>();
    let mut threads = Vec::new();
    for client in 0..2u64 {
        let addr = addr.clone();
        let params = ctx.params.clone();
        let opts = opts.clone();
        let codec = SelectiveCodec::new(ctx.clone());
        let pk = pk.clone();
        let mask = mask.clone();
        let rejoined_tx = rejoined_tx.clone();
        threads.push(std::thread::spawn(move || {
            let (mut sess, _) =
                ClientSession::connect(&addr, client, params.clone(), opts.clone()).unwrap();
            // round 0
            let dl = sess.recv_round(0, Some(shape)).unwrap();
            assert!(dl.down.participate && !dl.down.has_agg);
            let mut rng = ChaChaRng::from_seed(100 + client, 0);
            let upd =
                codec.encrypt_update(&client_model(total, client, 0), &mask, &pk, &mut rng);
            sess.upload(0, 0.5, &upd, None).unwrap();
            if client == 1 {
                // lose the connection between rounds, then rejoin the slot
                drop(sess);
                let (s2, next) =
                    ClientSession::connect(&addr, client, params, opts).unwrap();
                assert_eq!(next, 1, "rejoin should resume at round 1");
                sess = s2;
                rejoined_tx.send(()).unwrap();
            }
            // round 1 carries round 0's aggregate
            let dl = sess.recv_round(1, Some(shape)).unwrap();
            assert!(dl.down.participate && dl.down.has_agg);
            assert!(dl.agg.is_some());
            let mut rng = ChaChaRng::from_seed(100 + client, 1);
            let upd =
                codec.encrypt_update(&client_model(total, client, 1), &mask, &pk, &mut rng);
            sess.upload(1, 0.5, &upd, None).unwrap();
            // fin
            let dl = sess.recv_round(2, Some(shape)).unwrap();
            assert!(dl.down.fin);
        }));
    }
    drop(rejoined_tx);

    hub.wait_for_clients(2, Duration::from_secs(10)).unwrap();
    let plan = |alpha: f64| DownBegin {
        alpha,
        alpha_mass: 0.0,
        n_cts: 0,
        n_plain: 0,
        total: 0,
        participate: true,
        has_agg: false,
        fin: false,
    };
    // round 0: no aggregate yet
    let out = hub.broadcast_round(0, &[(0, plan(0.5)), (1, plan(0.5))], None);
    assert!(out.failed.is_empty());
    hub.set_next_round(1);
    let outcome = hub.collect_round(&[(0, Some(0.5)), (1, Some(0.5))], shape, &icfg(0));
    assert_eq!(outcome.arrivals.len(), 2, "failed: {:?}", outcome.failed);
    let oracle0 = native::aggregate(
        &[encrypt(0, 0), encrypt(1, 0)],
        &[0.5, 0.5],
        &codec.ctx.params,
    );
    let mut arrivals = outcome.arrivals;
    arrivals.sort_by_key(|a| a.client);
    let agg0 = native::aggregate(
        &[(*arrivals[0].update).clone(), (*arrivals[1].update).clone()],
        &[0.5, 0.5],
        &codec.ctx.params,
    );
    assert_eq!(agg0.plain, oracle0.plain);
    for (a, b) in agg0.cts.iter().zip(oracle0.cts.iter()) {
        assert_eq!(a.c0, b.c0);
        assert_eq!(a.c1, b.c1);
    }

    // wait until client 1 has rejoined its slot before round 1's downlink
    rejoined_rx
        .recv_timeout(Duration::from_secs(10))
        .expect("client 1 never rejoined");
    let round1 = DownBegin {
        alpha: 0.5,
        alpha_mass: 1.0,
        n_cts: agg0.cts.len(),
        n_plain: agg0.plain.len(),
        total: agg0.total,
        participate: true,
        has_agg: true,
        fin: false,
    };
    let out = hub.broadcast_round(1, &[(0, round1), (1, round1)], Some(&agg0));
    assert!(out.failed.is_empty(), "rejoined slot unusable: {:?}", out.failed);
    let outcome = hub.collect_round(&[(0, Some(0.5)), (1, Some(0.5))], shape, &icfg(1));
    assert_eq!(
        outcome.arrivals.len(),
        2,
        "round 1 after rejoin failed: {:?}",
        outcome.failed
    );
    // fin downlink so the client threads exit
    let fin = DownBegin {
        alpha: 0.0,
        alpha_mass: 0.0,
        n_cts: 0,
        n_plain: 0,
        total: 0,
        participate: false,
        has_agg: false,
        fin: true,
    };
    let out = hub.broadcast_round(2, &[(0, fin), (1, fin)], None);
    assert!(out.failed.is_empty());
    for t in threads {
        t.join().unwrap();
    }
    hub.shutdown();
}
