//! Transport acceptance gates: a loopback TCP round with concurrent clients
//! (one disconnecting mid-upload) must produce a bitwise-identical aggregate
//! to the in-process engine, report the disconnecting client as a dropped
//! straggler, bound its accept loop, and reject malformed wire input without
//! panicking or poisoning the round. No artifacts required — everything runs
//! on the pure-Rust crypto substrate.

use fedml_he::agg_engine::{Engine, EngineConfig, StreamingAggregator};
use fedml_he::ckks::serialize::ciphertext_shard_to_bytes;
use fedml_he::ckks::{CkksContext, PublicKey};
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::he_agg::{native, EncryptedUpdate, EncryptionMask, SelectiveCodec};
use fedml_he::transport::frame::encode_begin;
use fedml_he::transport::{
    upload_encrypt_streaming, upload_partial_then_disconnect, upload_update, write_frame,
    FrameKind, IntakeConfig, TcpIntake, UpdateShape, UploadConfig, UNIDENTIFIED_CLIENT,
};
use std::io::Write;
use std::net::TcpStream;
use std::time::Duration;

const TOTAL: usize = 1100;

fn fixture(
    n_clients: usize,
) -> (
    SelectiveCodec,
    PublicKey,
    EncryptionMask,
    Vec<Vec<f32>>,
    Vec<f64>,
) {
    let ctx = CkksContext::new(256, 4, 40).unwrap();
    let codec = SelectiveCodec::new(ctx);
    let mut rng = ChaChaRng::from_seed(71, 0);
    let (pk, _sk) = codec.ctx.keygen(&mut rng);
    let sens: Vec<f32> = (0..TOTAL).map(|i| ((i * 37) % 113) as f32).collect();
    let mask = EncryptionMask::top_p(&sens, 0.45);
    let models: Vec<Vec<f32>> = (0..n_clients)
        .map(|c| {
            (0..TOTAL)
                .map(|i| ((i + c * 97) as f32 * 0.004).sin())
                .collect()
        })
        .collect();
    let alphas: Vec<f64> = vec![1.0 / n_clients as f64; n_clients];
    (codec, pk, mask, models, alphas)
}

fn encrypt_all(
    codec: &SelectiveCodec,
    models: &[Vec<f32>],
    mask: &EncryptionMask,
    pk: &PublicKey,
) -> Vec<EncryptedUpdate> {
    models
        .iter()
        .enumerate()
        .map(|(c, m)| {
            let mut rng = ChaChaRng::from_seed(100 + c as u64, 0);
            codec.encrypt_update(m, mask, pk, &mut rng)
        })
        .collect()
}

fn intake_cfg(round_id: u64, expected: usize) -> IntakeConfig {
    IntakeConfig {
        round_id,
        expected_uploads: expected,
        quorum: None,
        straggler_timeout: Duration::from_secs(5),
        max_wait: Duration::from_secs(20),
        io_timeout: Duration::from_secs(5),
    }
}

#[test]
fn tcp_round_with_disconnect_matches_in_process_engine_bitwise() {
    // ≥ 4 concurrent clients, one disconnecting mid-upload: the round
    // completes, counts the disconnect as a dropped straggler, and the
    // aggregate is bitwise-identical to the in-process engine over the
    // clients that landed.
    let n = 5;
    let (codec, pk, mask, models, alphas) = fixture(n);
    let updates = encrypt_all(&codec, &models, &mask, &pk);
    let oracle = native::aggregate(&updates[..4], &alphas[..4], &codec.ctx.params);

    let shape = UpdateShape::for_round(&codec.ctx, &mask);
    let intake = TcpIntake::bind("127.0.0.1:0", codec.ctx.params.clone(), shape).unwrap();
    let addr = intake.local_addr().unwrap().to_string();
    let mut handles = Vec::new();
    for (c, upd) in updates.iter().cloned().enumerate() {
        let addr = addr.clone();
        let alpha = alphas[c];
        handles.push(std::thread::spawn(move || {
            let cfg = UploadConfig {
                round_id: 3,
                client: c as u64,
                alpha,
                ..UploadConfig::default()
            };
            if c == 4 {
                // BEGIN + one ciphertext chunk, then drop the socket
                upload_partial_then_disconnect(&addr, &cfg, &upd, 1).unwrap();
            } else {
                let receipt = upload_update(&addr, &cfg, &upd).unwrap();
                assert!(receipt.acked);
                assert_eq!(receipt.ct_frames, upd.cts.len());
            }
        }));
    }
    let outcome = intake.collect_round(&intake_cfg(3, n)).unwrap();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(outcome.arrivals.len(), 4);
    assert_eq!(outcome.failed, vec![4u64]);
    assert!(outcome.bytes_received > 0);
    // wall-clock stamps are monotone and within the intake window
    for w in outcome.arrivals.windows(2) {
        assert!(w[0].arrival_secs <= w[1].arrival_secs);
    }

    let engine = StreamingAggregator::new(
        &codec.ctx.params,
        EngineConfig {
            engine: Engine::Pipeline,
            shards: 4,
            quorum: None,
            straggler_timeout_secs: 5.0,
        },
    );
    let mut round = engine.begin_round(Some(&mask));
    for a in outcome.arrivals {
        round.offer(a).unwrap();
    }
    let (agg, mut stats) = round.seal().unwrap();
    stats.offered += outcome.failed.len();
    stats.dropped_stragglers += outcome.failed.len();
    assert_eq!(stats.offered, 5);
    assert_eq!(stats.accepted, 4);
    assert_eq!(stats.dropped_stragglers, 1);
    let expect_mass: f64 = alphas[..4].iter().sum();
    assert!((stats.alpha_mass - expect_mass).abs() < 1e-12);

    assert_eq!(agg.cts.len(), oracle.cts.len());
    for (a, b) in agg.cts.iter().zip(oracle.cts.iter()) {
        assert_eq!(a.c0, b.c0, "c0 limbs differ from the in-process engine");
        assert_eq!(a.c1, b.c1, "c1 limbs differ from the in-process engine");
        assert_eq!(a.n_values, b.n_values);
        assert!((a.scale - b.scale).abs() < 1e-9);
    }
    assert_eq!(agg.plain, oracle.plain);
}

#[test]
fn streaming_encrypt_upload_is_bitwise_identical_to_staged() {
    // upload_encrypt_streaming overlaps encryption with the socket write;
    // the server must reassemble exactly the update encrypt_update builds
    // from the same rng state.
    let (codec, pk, mask, models, _alphas) = fixture(1);
    let expected = {
        let mut rng = ChaChaRng::from_seed(500, 0);
        codec.encrypt_update(&models[0], &mask, &pk, &mut rng)
    };
    let shape = UpdateShape::for_round(&codec.ctx, &mask);
    let intake = TcpIntake::bind("127.0.0.1:0", codec.ctx.params.clone(), shape).unwrap();
    let addr = intake.local_addr().unwrap().to_string();
    let outcome = std::thread::scope(|s| {
        s.spawn(|| {
            let mut rng = ChaChaRng::from_seed(500, 0);
            let cfg = UploadConfig {
                round_id: 9,
                client: 42,
                alpha: 1.0,
                ..UploadConfig::default()
            };
            let receipt = upload_encrypt_streaming(
                &addr, &cfg, &codec, &models[0], &mask, &pk, &mut rng,
            )
            .unwrap();
            assert!(receipt.acked);
            assert_eq!(receipt.ct_frames, expected.cts.len());
        });
        intake.collect_round(&intake_cfg(9, 1))
    })
    .unwrap();
    assert_eq!(outcome.arrivals.len(), 1);
    assert!(outcome.failed.is_empty());
    let got = &outcome.arrivals[0];
    assert_eq!(got.client, 42);
    assert!((got.alpha - 1.0).abs() < 1e-15);
    assert_eq!(got.update.total, expected.total);
    assert_eq!(got.update.plain, expected.plain);
    assert_eq!(got.update.cts.len(), expected.cts.len());
    for (a, b) in got.update.cts.iter().zip(expected.cts.iter()) {
        assert_eq!(a, b, "wire roundtrip changed a ciphertext");
    }
}

#[test]
fn malformed_uploads_fail_their_connection_not_the_round() {
    // Three concurrent connections: one valid, one with a shape-skewed
    // BEGIN, one full-limb-range violation (limb-count mismatch). The round
    // completes from the valid upload; the identified failures land in
    // `failed` and settle their slots.
    let (codec, pk, mask, models, alphas) = fixture(2);
    let updates = encrypt_all(&codec, &models, &mask, &pk);
    let shape = UpdateShape::for_round(&codec.ctx, &mask);
    let intake = TcpIntake::bind("127.0.0.1:0", codec.ctx.params.clone(), shape).unwrap();
    let addr = intake.local_addr().unwrap().to_string();

    let mut handles = Vec::new();
    // valid upload from client 0
    {
        let addr = addr.clone();
        let upd = updates[0].clone();
        let alpha = alphas[0];
        handles.push(std::thread::spawn(move || {
            let cfg = UploadConfig {
                round_id: 1,
                client: 0,
                alpha,
                ..UploadConfig::default()
            };
            upload_update(&addr, &cfg, &upd).unwrap();
        }));
    }
    // client 7: BEGIN declaring one ciphertext too many
    {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut s = TcpStream::connect(&addr).unwrap();
            let p = encode_begin(7, 0.5, shape.n_cts + 1, shape.n_plain, shape.total);
            let _ = write_frame(&mut s, 1, FrameKind::Begin, 0, &p);
            let _ = s.flush();
        }));
    }
    // client 8: valid BEGIN, then a ciphertext chunk carrying only a partial
    // limb range — a limb-count mismatch on the wire
    {
        let addr = addr.clone();
        let upd = updates[1].clone();
        handles.push(std::thread::spawn(move || {
            let mut s = TcpStream::connect(&addr).unwrap();
            let p = encode_begin(8, 0.5, shape.n_cts, shape.n_plain, shape.total);
            let _ = write_frame(&mut s, 1, FrameKind::Begin, 0, &p);
            let partial = ciphertext_shard_to_bytes(&upd.cts[0], 0, 2);
            let _ = write_frame(&mut s, 1, FrameKind::CtChunk, 0, &partial);
            let _ = s.flush();
        }));
    }

    let outcome = intake.collect_round(&intake_cfg(1, 3)).unwrap();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(outcome.arrivals.len(), 1);
    assert_eq!(outcome.arrivals[0].client, 0);
    let mut failed = outcome.failed.clone();
    failed.sort_unstable();
    assert_eq!(failed, vec![7, 8]);

    // the surviving upload still seals into a valid round
    let engine = StreamingAggregator::new(
        &codec.ctx.params,
        EngineConfig {
            engine: Engine::Pipeline,
            shards: 2,
            quorum: None,
            straggler_timeout_secs: 5.0,
        },
    );
    let mut round = engine.begin_round(Some(&mask));
    for a in outcome.arrivals {
        round.offer(a).unwrap();
    }
    let (agg, stats) = round.seal().unwrap();
    assert_eq!(stats.accepted, 1);
    let oracle = native::aggregate(&updates[..1], &alphas[..1], &codec.ctx.params);
    for (a, b) in agg.cts.iter().zip(oracle.cts.iter()) {
        assert_eq!(a.c0, b.c0);
        assert_eq!(a.c1, b.c1);
    }
}

#[test]
fn anonymous_probe_does_not_displace_a_participant() {
    // A garbage connection that never presents a valid BEGIN is recorded in
    // `failed` but must not consume the participant's slot: the real upload
    // arriving afterwards still completes the round.
    let (codec, pk, mask, models, alphas) = fixture(1);
    let updates = encrypt_all(&codec, &models, &mask, &pk);
    let shape = UpdateShape::for_round(&codec.ctx, &mask);
    let intake = TcpIntake::bind("127.0.0.1:0", codec.ctx.params.clone(), shape).unwrap();
    let addr = intake.local_addr().unwrap().to_string();
    let handle = {
        let addr = addr.clone();
        let upd = updates[0].clone();
        let alpha = alphas[0];
        std::thread::spawn(move || {
            // probe first: pure garbage, then close
            {
                let mut s = TcpStream::connect(&addr).unwrap();
                let _ = s.write_all(&[0xABu8; 128]);
                let _ = s.flush();
            }
            std::thread::sleep(Duration::from_millis(200));
            let cfg = UploadConfig {
                round_id: 6,
                client: 0,
                alpha,
                ..UploadConfig::default()
            };
            upload_update(&addr, &cfg, &upd).unwrap();
        })
    };
    let outcome = intake.collect_round(&intake_cfg(6, 1)).unwrap();
    handle.join().unwrap();
    assert_eq!(outcome.arrivals.len(), 1);
    assert_eq!(outcome.arrivals[0].client, 0);
    assert_eq!(outcome.failed, vec![UNIDENTIFIED_CLIENT]);
}

#[test]
fn duplicate_upload_is_discarded_not_double_counted() {
    // The same client uploading twice (lost-ACK retry or a forged id) must
    // contribute exactly one arrival — aggregating both would double its
    // FedAvg weight.
    let (codec, pk, mask, models, alphas) = fixture(1);
    let updates = encrypt_all(&codec, &models, &mask, &pk);
    let shape = UpdateShape::for_round(&codec.ctx, &mask);
    let intake = TcpIntake::bind("127.0.0.1:0", codec.ctx.params.clone(), shape).unwrap();
    let addr = intake.local_addr().unwrap().to_string();
    let handle = {
        let addr = addr.clone();
        let upd = updates[0].clone();
        let alpha = alphas[0];
        std::thread::spawn(move || {
            let cfg = UploadConfig {
                round_id: 4,
                client: 0,
                alpha,
                ..UploadConfig::default()
            };
            upload_update(&addr, &cfg, &upd).unwrap();
            // retry: completes on the wire but must be discarded server-side
            let _ = upload_update(&addr, &cfg, &upd);
        })
    };
    let cfg = IntakeConfig {
        round_id: 4,
        expected_uploads: 2,
        quorum: Some(1),
        straggler_timeout: Duration::from_millis(500),
        max_wait: Duration::from_secs(20),
        io_timeout: Duration::from_secs(5),
    };
    let outcome = intake.collect_round(&cfg).unwrap();
    handle.join().unwrap();
    assert_eq!(outcome.arrivals.len(), 1);
    assert_eq!(outcome.failed, vec![0]);
}

#[test]
fn quorum_early_stop_bounds_the_accept_loop() {
    // Expecting 3 uploads but only 1 arrives: with quorum 1 and a short
    // straggler timeout the intake stops a few hundred ms after the first
    // completion instead of waiting out max_wait.
    let (codec, pk, mask, models, alphas) = fixture(1);
    let updates = encrypt_all(&codec, &models, &mask, &pk);
    let shape = UpdateShape::for_round(&codec.ctx, &mask);
    let intake = TcpIntake::bind("127.0.0.1:0", codec.ctx.params.clone(), shape).unwrap();
    let addr = intake.local_addr().unwrap().to_string();
    let handle = {
        let addr = addr.clone();
        let upd = updates[0].clone();
        let alpha = alphas[0];
        std::thread::spawn(move || {
            let cfg = UploadConfig {
                round_id: 2,
                client: 0,
                alpha,
                ..UploadConfig::default()
            };
            upload_update(&addr, &cfg, &upd).unwrap();
        })
    };
    let cfg = IntakeConfig {
        round_id: 2,
        expected_uploads: 3,
        quorum: Some(1),
        straggler_timeout: Duration::from_millis(300),
        max_wait: Duration::from_secs(30),
        io_timeout: Duration::from_secs(5),
    };
    let outcome = intake.collect_round(&cfg).unwrap();
    handle.join().unwrap();
    assert_eq!(outcome.arrivals.len(), 1);
    assert!(
        outcome.elapsed_secs < 10.0,
        "accept loop ran {}s — early stop did not engage",
        outcome.elapsed_secs
    );
}
