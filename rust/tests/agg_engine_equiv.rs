//! Acceptance tests for the `agg_engine` subsystem through the public API:
//!
//! * `--engine pipeline --shards 4` produces an aggregated global model
//!   identical to `--engine sequential` on the same seed — decrypt-exact
//!   per ciphertext limb (bitwise) and bitwise for the plaintext remainder.
//! * the cohort scheduler sustains a ≥1,000,000-client population with K=16
//!   sampled per round (lazy materialization, flat memory).
//!
//! Pure-Rust paths only — no AOT artifacts required.

use fedml_he::agg_engine::{
    Arrival, CohortScheduler, Engine, Population, StreamingAggregator,
};
use fedml_he::ckks::CkksContext;
use fedml_he::coordinator::FlConfig;
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::he_agg::{native, EncryptedUpdate, EncryptionMask, SelectiveCodec};
use fedml_he::util::cli::Args;
use std::sync::Arc;

fn parse_cfg(cmdline: &str) -> FlConfig {
    FlConfig::from_args(&Args::parse_from(cmdline.split_whitespace().map(String::from))).unwrap()
}

/// Build a selectively-encrypted round: weighted clients, top-p mask.
fn round_fixture(
    n_clients: usize,
    total: usize,
    ratio: f64,
) -> (SelectiveCodec, Vec<EncryptedUpdate>, Vec<f64>, EncryptionMask) {
    let ctx = CkksContext::new(512, 4, 45).unwrap();
    let codec = SelectiveCodec::new(ctx);
    let mut rng = ChaChaRng::from_seed(404, 0);
    let (pk, _sk) = codec.ctx.keygen(&mut rng);
    let sens: Vec<f32> = (0..total).map(|i| ((i * 17) % 389) as f32).collect();
    let mask = EncryptionMask::top_p(&sens, ratio);
    let sizes: Vec<f64> = (0..n_clients).map(|c| 64.0 + (c * 37 % 100) as f64).collect();
    let mass: f64 = sizes.iter().sum();
    let alphas: Vec<f64> = sizes.iter().map(|s| s / mass).collect();
    let updates: Vec<EncryptedUpdate> = (0..n_clients)
        .map(|c| {
            let m: Vec<f32> = (0..total)
                .map(|i| ((i * 3 + c * 251) as f32 * 0.0011).sin())
                .collect();
            codec.encrypt_update(&m, &mask, &pk, &mut rng)
        })
        .collect();
    (codec, updates, alphas, mask)
}

/// The acceptance gate: `run --engine pipeline --shards 4` ≡ sequential on
/// the same seed. Ciphertexts are compared limb-by-limb (decrypt-exact means
/// the pre-decryption limbs are bitwise equal, so decryption is too), and
/// the plaintext remainder bitwise.
#[test]
fn pipeline_shards4_identical_to_sequential() {
    let seq_cfg = parse_cfg("run --engine sequential --seed 42");
    let pipe_cfg = parse_cfg("run --engine pipeline --shards 4 --seed 42");
    assert_eq!(seq_cfg.engine, Engine::Sequential);
    assert_eq!(pipe_cfg.engine, Engine::Pipeline);
    assert_eq!(pipe_cfg.shards, 4);

    let (codec, updates, alphas, _mask) = round_fixture(7, 3000, 0.35);

    // sequential engine: the seed's one-shot native aggregation
    let sequential = native::aggregate(&updates, &alphas, &codec.ctx.params);

    // pipeline engine: streamed in a scrambled arrival order
    let engine = StreamingAggregator::new(&codec.ctx.params, pipe_cfg.engine_config());
    let arrivals: Vec<Arrival> = updates
        .iter()
        .zip(alphas.iter())
        .enumerate()
        .map(|(i, (u, &alpha))| Arrival {
            client: i as u64,
            alpha,
            // deterministic scrambled completion times
            arrival_secs: ((i * 5 + 3) % 7) as f64,
            update: Arc::new(u.clone()),
        })
        .collect();
    let (pipelined, stats) = engine.aggregate(arrivals).unwrap();

    assert_eq!(stats.accepted, 7);
    assert_eq!(stats.dropped_stragglers, 0);
    assert_eq!(pipelined.total, sequential.total);
    assert_eq!(pipelined.cts.len(), sequential.cts.len());
    for (ct_idx, (a, b)) in pipelined.cts.iter().zip(sequential.cts.iter()).enumerate() {
        for limb in 0..codec.ctx.params.num_limbs() {
            assert_eq!(
                a.c0.limb(limb), b.c0.limb(limb),
                "ct {ct_idx} limb {limb}: c0 differs"
            );
            assert_eq!(
                a.c1.limb(limb), b.c1.limb(limb),
                "ct {ct_idx} limb {limb}: c1 differs"
            );
        }
        assert_eq!(a.n_values, b.n_values);
        assert!((a.scale - b.scale).abs() < 1e-9);
    }
    // plaintext remainder: bitwise
    assert_eq!(pipelined.plain, sequential.plain);
}

/// Same gate across the bench shard sweep {1, 2, 4, 8}.
#[test]
fn all_shard_counts_agree() {
    let (codec, updates, alphas, _mask) = round_fixture(4, 1500, 0.5);
    let oracle = native::aggregate(&updates, &alphas, &codec.ctx.params);
    for shards in [1usize, 2, 4, 8] {
        let cfg = parse_cfg(&format!("run --engine pipeline --shards {shards}"));
        let engine = StreamingAggregator::new(&codec.ctx.params, cfg.engine_config());
        let arrivals: Vec<Arrival> = updates
            .iter()
            .zip(alphas.iter())
            .enumerate()
            .map(|(i, (u, &alpha))| Arrival {
                client: i as u64,
                alpha,
                arrival_secs: (4 - i) as f64,
                update: Arc::new(u.clone()),
            })
            .collect();
        let (got, _) = engine.aggregate(arrivals).unwrap();
        for (a, b) in got.cts.iter().zip(oracle.cts.iter()) {
            assert_eq!(a.c0, b.c0, "shards={shards}");
            assert_eq!(a.c1, b.c1, "shards={shards}");
        }
        assert_eq!(got.plain, oracle.plain, "shards={shards}");
    }
}

/// Population-scale cohort scheduling: 1M registered clients, K=16 per
/// round, lazily materialized. Memory stays flat because the scheduler
/// allocates O(K) per sample; we run many rounds to demonstrate sustained
/// operation.
#[test]
fn million_client_population_sustained() {
    let cfg = parse_cfg("run --engine pipeline --population 1000000");
    assert_eq!(cfg.population, Some(1_000_000));
    let sched = CohortScheduler::new(Population::new(cfg.population.unwrap(), cfg.seed), 16);
    let mut all_ids: std::collections::HashSet<u64> = std::collections::HashSet::new();
    for round in 0..200 {
        let cohort = sched.sample(round);
        assert_eq!(cohort.members.len(), 16);
        let mass: f64 = cohort.members.iter().map(|m| m.alpha).sum();
        assert!((mass - 1.0).abs() < 1e-9);
        for m in &cohort.members {
            assert!(m.id < 1_000_000);
            assert!(m.data_size >= 64);
            all_ids.insert(m.id);
        }
    }
    // 200 rounds × 16 from a 1M population: collisions are rare, so the
    // scheduler really is ranging over the whole registry.
    assert!(all_ids.len() > 3000, "only {} distinct ids", all_ids.len());
}

/// A straggler-dropping streamed round over a sampled cohort decrypts to
/// the renormalized FedAvg over the accepted members.
#[test]
fn cohort_round_with_stragglers_end_to_end() {
    let sched = CohortScheduler::new(Population::new(1_000_000, 5), 6);
    let cohort = sched.sample(0);

    let ctx = CkksContext::new(256, 4, 40).unwrap();
    let codec = SelectiveCodec::new(ctx);
    let mut rng = ChaChaRng::from_seed(501, 0);
    let (pk, sk) = codec.ctx.keygen(&mut rng);
    let total = 700;
    let mask = EncryptionMask::full(total);
    let models: Vec<Vec<f32>> = cohort
        .members
        .iter()
        .map(|m| {
            (0..total)
                .map(|i| ((i as u64 + m.id) % 1000) as f32 * 1e-3)
                .collect()
        })
        .collect();
    let updates: Vec<EncryptedUpdate> = models
        .iter()
        .map(|m| codec.encrypt_update(m, &mask, &pk, &mut rng))
        .collect();

    let cfg = parse_cfg("run --engine pipeline --shards 4 --quorum 4 --straggler-timeout 1.0");
    let engine = StreamingAggregator::new(&codec.ctx.params, cfg.engine_config());
    // members 4 and 5 (by arrival) are stragglers
    let times = [0.1, 0.2, 0.3, 0.4, 50.0, 60.0];
    let arrivals: Vec<Arrival> = updates
        .iter()
        .zip(cohort.members.iter())
        .zip(times.iter())
        .map(|((u, m), &t)| Arrival {
            client: m.id,
            alpha: m.alpha,
            arrival_secs: t,
            update: Arc::new(u.clone()),
        })
        .collect();
    let (agg, stats) = engine.aggregate(arrivals).unwrap();
    assert_eq!(stats.accepted, 4);
    assert_eq!(stats.dropped_stragglers, 2);

    let mut got = codec.decrypt_update(&agg, &mask, &sk);
    for v in got.iter_mut() {
        *v = (*v as f64 / stats.alpha_mass) as f32;
    }
    let renorm: Vec<f64> = cohort.members[..4]
        .iter()
        .map(|m| m.alpha / stats.alpha_mass)
        .collect();
    let expected = native::plain_fedavg(&models[..4], &renorm);
    for j in 0..total {
        assert!(
            (got[j] - expected[j]).abs() < 1e-4,
            "j={j}: {} vs {}",
            got[j],
            expected[j]
        );
    }
}
