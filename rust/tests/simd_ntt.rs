//! §Perf differential gates for the runtime-dispatched NTT kernels
//! (`ckks::simd`). Every kernel reachable through dispatch — the portable
//! scalar loops, the detected vector kernel, and whatever `active()` picked
//! for this process — must be **bitwise identical** to the seed reference
//! butterflies kept in `ntt.rs`, across every generated prime and the full
//! ring-degree range, on random and extremal coefficient patterns. The
//! weighted-sum trait methods get the same treatment against plain Barrett
//! arithmetic.
//!
//! CI runs this binary twice: once with auto-detection (exercising the
//! vector kernel on AVX2 runners) and once under `FEDML_HE_NTT_KERNEL=scalar`
//! (pinning the forced-scalar override end to end).

use fedml_he::ckks::modarith::Barrett;
use fedml_he::ckks::ntt::NttTables;
use fedml_he::ckks::params::generate_ntt_primes;
use fedml_he::ckks::simd::{self, NttKernel};
use fedml_he::crypto::prng::ChaChaRng;

const DEGREES: [usize; 6] = [16, 64, 256, 1024, 4096, 8192];

/// One full differential sweep of `k` against the reference butterflies:
/// forward and inverse transforms bitwise equal, outputs fully reduced,
/// exact roundtrip — for every generated prime × ring degree, on a random
/// vector plus the extremal patterns (all q−1, all zero, spike at n−1).
fn sweep(k: &dyn NttKernel) {
    for &q in &generate_ntt_primes(4) {
        for n in DEGREES {
            let t = NttTables::new(q, n);
            let mut rng = ChaChaRng::from_seed(q ^ n as u64, 7);
            let mut patterns: Vec<Vec<u64>> = vec![
                (0..n).map(|_| rng.uniform_u64(q)).collect(),
                vec![q - 1; n],
                vec![0; n],
            ];
            let mut spike = vec![0u64; n];
            spike[n - 1] = q - 1;
            patterns.push(spike);
            for orig in patterns {
                let mut got = orig.clone();
                let mut want = orig.clone();
                t.forward_with(k, &mut got);
                t.forward_reference(&mut want);
                assert_eq!(got, want, "[{}] forward mismatch q={q} n={n}", k.name());
                assert!(
                    got.iter().all(|&x| x < q),
                    "[{}] forward output not fully reduced q={q} n={n}",
                    k.name()
                );
                t.inverse_with(k, &mut got);
                t.inverse_reference(&mut want);
                assert_eq!(got, want, "[{}] inverse mismatch q={q} n={n}", k.name());
                assert!(
                    got.iter().all(|&x| x < q),
                    "[{}] inverse output not fully reduced q={q} n={n}",
                    k.name()
                );
                assert_eq!(got, orig, "[{}] roundtrip mismatch q={q} n={n}", k.name());
            }
        }
    }
}

#[test]
fn scalar_kernel_matches_reference_everywhere() {
    sweep(simd::scalar());
}

#[test]
fn detected_simd_kernel_matches_reference_everywhere() {
    if let Some(k) = simd::detected_simd() {
        assert!(k.is_simd());
        sweep(k);
    }
    // Hosts without a vector unit have nothing to differentially test here;
    // the scalar sweep above is the whole story for them.
}

#[test]
fn dispatch_paths_match_reference_everywhere() {
    // Both values `kernel_for` can resolve to, plus the process-wide pick
    // (which honours FEDML_HE_NTT_KERNEL — CI runs this both ways).
    let forced = simd::kernel_for(Some("scalar"));
    assert_eq!(forced.name(), "scalar");
    sweep(forced);
    sweep(simd::kernel_for(None));
    sweep(simd::active());
}

#[test]
fn weighted_kernel_methods_match_scalar_barrett_math() {
    let mut kernels: Vec<&dyn NttKernel> = vec![simd::scalar()];
    if let Some(k) = simd::detected_simd() {
        kernels.push(k);
    }
    for &q in &generate_ntt_primes(4) {
        let br = Barrett::new(q);
        // Lengths straddle the 4-lane width: pure tails, exact multiples,
        // and multiples-plus-tail all take distinct code paths.
        for len in [1usize, 3, 4, 7, 64, 1001] {
            let mut rng = ChaChaRng::from_seed(q ^ len as u64, 9);
            let src: Vec<u64> = (0..len).map(|_| rng.uniform_u64(q)).collect();
            let w = rng.uniform_u64(q);
            for k in &kernels {
                let mut got = vec![0u64; len];
                k.weighted_init(&mut got, &src, w, br);
                let mut want = vec![0u64; len];
                for (d, &s) in want.iter_mut().zip(&src) {
                    *d = br.mul(s, w);
                }
                assert_eq!(got, want, "[{}] weighted_init q={q} len={len}", k.name());

                // Accumulate on top of near-maximal accumulators: the sums
                // land just under the 2^62 Barrett bound callers fold at.
                let base: Vec<u64> = (0..len).map(|i| (1u64 << 61) - 1 - i as u64).collect();
                let mut got = base.clone();
                k.weighted_accumulate(&mut got, &src, w, br);
                let mut want = base.clone();
                for (d, &s) in want.iter_mut().zip(&src) {
                    *d += br.mul(s, w);
                }
                assert_eq!(
                    got, want,
                    "[{}] weighted_accumulate q={q} len={len}",
                    k.name()
                );

                // Fold those accumulators back to [0, q).
                k.reduce_slice(&mut got, br);
                let want_red: Vec<u64> = want.iter().map(|&t| br.reduce(t)).collect();
                assert_eq!(got, want_red, "[{}] reduce_slice q={q} len={len}", k.name());
                assert!(got.iter().all(|&x| x < q));
            }
        }
    }
}
