//! Threshold-key dropout recovery (Appendix B): a dropped party's secret
//! share is escrowed t-of-n via Shamir, reconstructed by a surviving quorum,
//! and distributed decryption still succeeds with the resurrected share.
//!
//! Runs on the pure-Rust crypto substrate — no artifacts needed.

use fedml_he::ckks::threshold::{
    combine_partials, combine_public_key, common_reference, partial_decrypt, party_keygen,
    share_from_bytes, share_to_bytes, ThresholdParty,
};
use fedml_he::ckks::{CkksContext, RnsPoly};
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::crypto::shamir;

fn max_abs_err(values: &[f64], decoded: &[f64]) -> f64 {
    values
        .iter()
        .zip(decoded.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max)
}

#[test]
fn quorum_reconstructs_dropped_share_and_decrypts() {
    let ctx = CkksContext::new(512, 4, 45).unwrap();
    let params = &ctx.params;
    let mut rng = ChaChaRng::from_seed(61, 0);

    // 3-party threshold key agreement over the CRS.
    let a = common_reference(params, 2024);
    let parties: Vec<ThresholdParty> = (0..3)
        .map(|k| party_keygen(params, k, &a, &mut rng))
        .collect();
    let shares: Vec<&RnsPoly> = parties.iter().map(|p| &p.b_share_ntt).collect();
    let pk = combine_public_key(params, &a, &shares);

    // At setup, every party's secret share is Shamir-escrowed 2-of-3 across
    // the cohort (the escrow for party 1 is what we exercise below).
    let escrow_bytes = share_to_bytes(&parties[1].s_ntt);
    let escrow = shamir::split_bytes(&escrow_bytes, 2, 3, &mut rng);

    // Encrypt an aggregate under the joint key.
    let values: Vec<f64> = (0..ctx.batch()).map(|i| (i as f64 * 0.013).sin()).collect();
    let ct = ctx.encrypt_values(&values, &pk, &mut rng);

    // Party 1 drops. Parties 0 and 2 form the recovery quorum and
    // reconstruct its share from their escrow pieces.
    let recovered_bytes = shamir::reconstruct_bytes(&[&escrow[0], &escrow[2]], escrow_bytes.len());
    assert_eq!(recovered_bytes, escrow_bytes, "escrow roundtrip must be exact");
    let recovered_share = share_from_bytes(params, &recovered_bytes).unwrap();
    let resurrected = ThresholdParty {
        id: 1,
        s_ntt: recovered_share,
        b_share_ntt: parties[1].b_share_ntt.clone(),
    };

    // Distributed decryption with the resurrected party succeeds …
    let deciders = [&parties[0], &resurrected, &parties[2]];
    let partials: Vec<RnsPoly> = deciders
        .iter()
        .map(|p| partial_decrypt(params, p, &ct, &mut rng))
        .collect();
    let m = combine_partials(params, &ct, &partials);
    let decoded = ctx.encoder.decode(&m, ct.n_values, ct.scale);
    assert!(
        max_abs_err(&values, &decoded) < 1e-4,
        "decryption with the reconstructed share must succeed"
    );

    // … while the survivors alone (no reconstruction) cannot decrypt.
    let partials: Vec<RnsPoly> = [&parties[0], &parties[2]]
        .iter()
        .map(|p| partial_decrypt(params, p, &ct, &mut rng))
        .collect();
    let m = combine_partials(params, &ct, &partials);
    let decoded = ctx.encoder.decode(&m, ct.n_values, ct.scale);
    assert!(
        max_abs_err(&values, &decoded) > 1.0,
        "a sub-quorum partial set must not decrypt"
    );
}

#[test]
fn sub_quorum_escrow_reveals_nothing_usable() {
    // One escrow piece alone reconstructs garbage (t = 2): the rebuilt share
    // either fails validation or differs from the real share.
    let ctx = CkksContext::new(256, 3, 40).unwrap();
    let params = &ctx.params;
    let mut rng = ChaChaRng::from_seed(62, 0);
    let a = common_reference(params, 7);
    let party = party_keygen(params, 0, &a, &mut rng);
    let bytes = share_to_bytes(&party.s_ntt);
    let escrow = shamir::split_bytes(&bytes, 2, 3, &mut rng);
    let lone = shamir::reconstruct_bytes(&[&escrow[0]], bytes.len());
    assert_ne!(lone, bytes);
    match share_from_bytes(params, &lone) {
        Err(_) => {} // out-of-range coefficients rejected
        Ok(poly) => assert_ne!(poly, party.s_ntt),
    }
}

#[test]
fn escrow_length_validation() {
    let ctx = CkksContext::new(128, 2, 30).unwrap();
    let params = &ctx.params;
    let mut rng = ChaChaRng::from_seed(63, 0);
    let a = common_reference(params, 1);
    let party = party_keygen(params, 0, &a, &mut rng);
    let bytes = share_to_bytes(&party.s_ntt);
    assert_eq!(bytes.len(), 2 * 128 * 4);
    assert!(share_from_bytes(params, &bytes[..bytes.len() - 4]).is_err());
    // roundtrip is exact
    let back = share_from_bytes(params, &bytes).unwrap();
    assert_eq!(back, party.s_ntt);
}
