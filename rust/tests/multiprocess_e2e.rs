//! The ISSUE-5 acceptance gate at OS-process scale: one `serve` process and
//! three independent `join` processes complete a full multi-round training
//! run over loopback TCP — keys distributed out-of-band via the task-key
//! file — and every process's final model is **bitwise identical** to the
//! in-process `--transport sim` run with the same seed.
//!
//! Runs artifact-free (synthetic model); `CARGO_BIN_EXE_fedml-he` is built
//! by cargo for integration tests. The same gate runs twice: once on the
//! dense ciphertext wire and once under `--ct-wire seed`, where clients
//! encrypt symmetrically and ship 32-byte a-part seeds the server expands
//! lazily — the final model must not change by a single bit.

use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_fedml-he")
}

fn wait_with_timeout(child: &mut Child, secs: u64, name: &str) -> std::process::ExitStatus {
    let deadline = Instant::now() + Duration::from_secs(secs);
    loop {
        match child.try_wait().unwrap() {
            Some(status) => return status,
            None => {
                if Instant::now() >= deadline {
                    child.kill().ok();
                    let _ = child.wait();
                    panic!("{name} did not exit within {secs}s");
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// One full sim-vs-serve/join bitwise gate. `tag` keeps the scratch dirs of
/// the dense and seed cases apart; `extra` is appended to every task
/// invocation (same spec on both sides — the task key re-pins it anyway).
fn run_bitwise_gate(tag: &str, extra: &[&str]) {
    let dir = std::env::temp_dir().join(format!("fedml_he_mp_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let sim_model = dir.join("sim.bin");
    let serve_model = dir.join("serve.bin");
    let task_key = dir.join("task.key");
    let addr_file = dir.join("addr");
    let common = [
        "--model",
        "synthetic",
        "--synthetic-params",
        "2048",
        "--clients",
        "3",
        "--rounds",
        "3",
        "--local-steps",
        "2",
        "--seed",
        "29",
        "--eval-every",
        "0",
        "--engine",
        "pipeline",
        "--shards",
        "2",
    ];

    // in-process simulator reference
    let status = Command::new(bin())
        .arg("run")
        .args(common)
        .args(extra)
        .args(["--transport", "sim", "--out-model", sim_model.to_str().unwrap()])
        .stdout(Stdio::null())
        .status()
        .unwrap();
    assert!(status.success(), "sim reference run failed");

    // one serve + three join OS processes over loopback
    let mut serve = Command::new(bin())
        .arg("serve")
        .args(common)
        .args(extra)
        .args([
            "--listen",
            "127.0.0.1:0",
            "--task-key",
            task_key.to_str().unwrap(),
            "--addr-file",
            addr_file.to_str().unwrap(),
            "--join-wait",
            "60",
            "--out-model",
            serve_model.to_str().unwrap(),
        ])
        .stdout(Stdio::null())
        .spawn()
        .unwrap();
    let mut joins: Vec<(std::path::PathBuf, Child)> = Vec::new();
    for id in 0..3 {
        let out = dir.join(format!("join{id}.bin"));
        let child = Command::new(bin())
            .arg("join")
            .args([
                "--task-key",
                task_key.to_str().unwrap(),
                "--addr-file",
                addr_file.to_str().unwrap(),
                "--client-id",
                &id.to_string(),
                "--key-wait",
                "60",
                "--connect-retry",
                "60",
                "--out-model",
                out.to_str().unwrap(),
            ])
            .stdout(Stdio::null())
            .spawn()
            .unwrap();
        joins.push((out, child));
    }
    let status = wait_with_timeout(&mut serve, 120, "serve");
    assert!(status.success(), "serve process failed");
    for (i, (_, child)) in joins.iter_mut().enumerate() {
        let status = wait_with_timeout(child, 60, "join");
        assert!(status.success(), "join {i} failed");
    }

    // bitwise identity: sim == serve == every join
    let sim_bytes = std::fs::read(&sim_model).unwrap();
    assert_eq!(sim_bytes.len(), 2048 * 4);
    let serve_bytes = std::fs::read(&serve_model).unwrap();
    assert_eq!(
        sim_bytes, serve_bytes,
        "serve final model is not bitwise-identical to the sim run"
    );
    for (path, _) in &joins {
        assert_eq!(
            std::fs::read(path).unwrap(),
            sim_bytes,
            "a join process's final model diverged"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_plus_three_join_processes_match_sim_bitwise() {
    run_bitwise_gate("dense", &[]);
}

/// Same gate on the seed-expanded wire: `join` picks the mode up from the
/// task key, announces it at HELLO, uploads symmetric seeded ciphertexts,
/// and the serve process expands a-parts lazily during aggregation.
#[test]
fn serve_plus_three_join_processes_match_sim_bitwise_seed_wire() {
    run_bitwise_gate("seed", &["--ct-wire", "seed"]);
}
