//! §Perf acceptance gate: the encrypt/decrypt/weighted-sum hot paths must
//! perform **zero heap allocations** in the steady state (after one warm-up
//! call per buffer shape). A counting wrapper around the system allocator
//! observes every allocation made by this test binary; the measured loop
//! re-runs the `_into` kernels against pooled scratch and asserts the
//! counter does not move.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use fedml_he::agg_engine::{Arrival, Engine, EngineConfig, StreamingAggregator};
use fedml_he::ckks::{
    decrypt_into, encrypt_into, keygen, ops, Ciphertext, CkksParams, CkksScratch, RnsPoly,
};
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::he_agg::{CtArena, EncryptedUpdate, EncryptionMask, SelectiveCodec};
use std::sync::Arc;

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn hot_paths_are_allocation_free_after_warmup() {
    let params = CkksParams::new(256, 3, 30).unwrap();
    let mut rng = ChaChaRng::from_seed(1, 0);
    let (pk, sk) = keygen(&params, &mut rng);
    let coeffs: Vec<i64> = (0..params.n).map(|i| (i as i64 % 17) - 8).collect();
    let pt = RnsPoly::from_signed(&params, &coeffs);

    let mut scratch = CkksScratch::new(&params);
    let mut ct = Ciphertext::zero(&params);
    let mut dec = RnsPoly::zero(&params);
    let mut agg = Ciphertext::zero(&params);
    // Fixed weighted-sum inputs (not mutated inside the measured loop).
    let in_a = fedml_he::ckks::encrypt(&params, &pk, &pt, 128, &mut rng);
    let in_b = fedml_he::ckks::encrypt(&params, &pk, &pt, 128, &mut rng);
    let inputs = [&in_a, &in_b];
    let alphas = [0.5, 0.5];

    // Warm-up: one call per path fills every pooled buffer to capacity.
    encrypt_into(&params, &pk, &pt, 128, &mut rng, &mut scratch, &mut ct);
    decrypt_into(&params, &sk, &ct, &mut scratch, &mut dec);
    ops::weighted_sum_refs_into(&inputs, &alphas, &params, &mut scratch, &mut agg);

    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..16 {
        encrypt_into(&params, &pk, &pt, 128, &mut rng, &mut scratch, &mut ct);
        decrypt_into(&params, &sk, &ct, &mut scratch, &mut dec);
        ops::weighted_sum_refs_into(&inputs, &alphas, &params, &mut scratch, &mut agg);
    }
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(
        after - before,
        0,
        "steady-state encrypt/decrypt/weighted-sum allocated {} time(s)",
        after - before
    );

    // Sanity: the loop really did useful work (fresh randomness each pass).
    assert!(ct.c0.limb(0).iter().any(|&x| x != 0));
    assert_eq!(agg.n_values, 128);
}

#[test]
fn seed_wire_encrypt_and_lazy_absorb_are_allocation_free_after_warmup() {
    // Seed-expanded wire hot paths (§Perf): a warm client round of symmetric
    // seeded encryption, and the server-side absorb of a lazily-parsed
    // seeded ciphertext (its a-part regenerated from the 32-byte seed into
    // the shard's pooled scratch), must both stay off the allocator.
    use fedml_he::agg_engine::{ShardAccumulator, ShardPlan};
    use fedml_he::ckks::encrypt_sym_seeded_into;
    use fedml_he::ckks::serialize::{ciphertext_seeded_from_bytes, ciphertext_seeded_to_bytes};
    let params = CkksParams::new(256, 3, 30).unwrap();
    let mut rng = ChaChaRng::from_seed(7, 0);
    let (_pk, sk) = keygen(&params, &mut rng);
    let coeffs: Vec<i64> = (0..params.n).map(|i| (i as i64 % 13) - 6).collect();
    let pt = RnsPoly::from_signed(&params, &coeffs);
    let mut scratch = CkksScratch::new(&params);
    let mut ct = Ciphertext::zero(&params);

    // Client side: warm-up fills the pooled error buffer, then the measured
    // seeded encrypts draw only from caller-owned storage.
    encrypt_sym_seeded_into(&params, &sk, &pt, 128, &mut rng, &mut scratch, &mut ct);
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..16 {
        encrypt_sym_seeded_into(&params, &sk, &pt, 128, &mut rng, &mut scratch, &mut ct);
    }
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(
        after - before,
        0,
        "steady-state seeded encrypt allocated {} time(s)",
        after - before
    );

    // Server side: round-trip through the compressed wire so the parsed twin
    // is lazy (seed kept, empty c1) — exactly what aggregation absorbs.
    let lazy = ciphertext_seeded_from_bytes(&ciphertext_seeded_to_bytes(&ct), &params).unwrap();
    assert!(lazy.a_seed.is_some());
    assert_eq!(lazy.c1.num_limbs(), 0);
    let upd = EncryptedUpdate {
        cts: vec![lazy],
        plain: Vec::new(),
        total: 128,
    };
    let plan = ShardPlan::new(1, 1, params.num_limbs(), 0);
    let mut acc = ShardAccumulator::new(&plan, 0, &params);
    let w = params.encode_weight(0.25);
    acc.absorb(&upd, &w); // warm-up for symmetry with the client half
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..16 {
        acc.absorb(&upd, &w);
    }
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(
        after - before,
        0,
        "steady-state lazy seeded absorb allocated {} time(s)",
        after - before
    );
    assert_eq!(acc.absorbed(), 17);
}

#[test]
fn warm_arena_rounds_stop_allocating_ciphertext_buffers() {
    // Pooled-ciphertext gate (§Perf): once the arena holds one round's
    // buffers, subsequent rounds draw every output ciphertext from the pool
    // — the two limb buffers per chunk (the model-scale allocations) must
    // disappear from the steady state, and the remaining per-call
    // bookkeeping must be exactly stable from round to round.
    let ctx = fedml_he::ckks::CkksContext::new(256, 3, 30).unwrap();
    let codec = SelectiveCodec::with_workers(ctx, 1);
    let mut rng = ChaChaRng::from_seed(21, 0);
    let (pk, _) = codec.ctx.keygen(&mut rng);
    let n_chunks = 8usize;
    let total = n_chunks * codec.ctx.batch();
    let model: Vec<f32> = (0..total).map(|i| (i as f32 * 0.01).sin()).collect();
    let mask = EncryptionMask::full(total);
    let arena = CtArena::new();
    let round = |rng: &mut ChaChaRng| {
        let mut n = 0usize;
        codec.encrypt_update_streamed_with_arena(&model, &mask, &pk, rng, &arena, |_, ct| {
            n += 1;
            arena.recycle(ct);
        });
        n
    };
    // Cold round: every ciphertext buffer is freshly allocated.
    let before = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(round(&mut rng), n_chunks);
    let cold = ALLOCS.load(Ordering::Relaxed) - before;
    // Warm rounds: all chunks come from the (now full) pool.
    let before = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(round(&mut rng), n_chunks);
    let warm1 = ALLOCS.load(Ordering::Relaxed) - before;
    let before = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(round(&mut rng), n_chunks);
    let warm2 = ALLOCS.load(Ordering::Relaxed) - before;
    assert!(
        cold >= warm1 + 2 * n_chunks,
        "warm arena round saved only {} of the {} ciphertext-buffer \
         allocations (cold {cold}, warm {warm1})",
        cold.saturating_sub(warm1),
        2 * n_chunks
    );
    assert_eq!(
        warm1, warm2,
        "steady-state arena rounds must have identical allocation counts"
    );
}

#[test]
fn steady_state_frame_reads_are_allocation_free() {
    // Pooled per-connection frame buffers (ROADMAP follow-up): once the
    // buffer has grown to the connection's largest frame, reading further
    // frames — including smaller ones — must not touch the allocator.
    use fedml_he::transport::{read_frame_into, write_frame, FrameKind};
    use std::io::Cursor;
    let mut wire = Vec::new();
    for i in 0..64u32 {
        let payload = vec![(i % 251) as u8; 1024 + ((i as usize * 37) % 512)];
        write_frame(&mut wire, 9, FrameKind::CtChunk, i, &payload).unwrap();
    }
    let mut buf = Vec::new();
    // warm-up pass grows the pooled buffer to the largest frame seen
    let mut cur = Cursor::new(&wire[..]);
    for _ in 0..64 {
        read_frame_into(&mut cur, 9, 1 << 20, &mut buf).unwrap();
    }
    let before = ALLOCS.load(Ordering::Relaxed);
    let mut cur = Cursor::new(&wire[..]);
    for i in 0..64u32 {
        let (kind, seq) = read_frame_into(&mut cur, 9, 1 << 20, &mut buf).unwrap();
        assert_eq!(kind, FrameKind::CtChunk);
        assert_eq!(seq, i);
    }
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(
        after - before,
        0,
        "steady-state frame reads allocated {} time(s)",
        after - before
    );
}

#[test]
fn streaming_admission_never_clones_updates() {
    // Quorum/straggler admission must move the round's already-owned
    // arrivals, never deep-copy an update: offering N model-scale updates is
    // O(N) small bookkeeping allocations, not O(N × model). A deep clone of
    // these 16 updates would cost hundreds of allocations (8 ciphertexts ×
    // 2 polynomials each, per arrival).
    let params = CkksParams::new(256, 3, 30).unwrap();
    let make_update = || {
        let cts: Vec<Ciphertext> = (0..8).map(|_| Ciphertext::zero(&params)).collect();
        Arc::new(EncryptedUpdate {
            cts,
            plain: vec![0.0f32; 1024],
            total: 2048,
        })
    };
    let cfg = EngineConfig {
        engine: Engine::Pipeline,
        shards: 2,
        quorum: Some(4),
        straggler_timeout_secs: 1.0,
    };
    let engine = StreamingAggregator::new(&params, cfg);
    let arrivals: Vec<Arrival> = (0..16)
        .map(|i| Arrival {
            client: i as u64,
            alpha: 1.0 / 16.0,
            arrival_secs: i as f64 * 0.01,
            update: make_update(),
        })
        .collect();
    let mut intake = engine.begin_round(None);
    let before = ALLOCS.load(Ordering::Relaxed);
    for a in arrivals {
        intake.offer(a).unwrap();
    }
    let after = ALLOCS.load(Ordering::Relaxed);
    assert!(
        after - before <= 8,
        "streaming admission allocated {} time(s) for 16 offers",
        after - before
    );
    assert_eq!(intake.offered(), 16);
}
