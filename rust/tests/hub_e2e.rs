//! Acceptance gates for the sharded epoll reactor backend (DESIGN.md §13):
//! the session-e2e matrix rerun against [`ReactorHub`], plus the scale
//! gate the thread-per-connection backend cannot express. Everything here
//! runs on the artifact-free synthetic workload, so these are tier-1
//! tests on any machine:
//!
//! * a full multi-round `--transport tcp --transport-backend hub` run is
//!   **bitwise identical** to the same-seed `--transport sim` run — with
//!   and without `--wire-auth mac`;
//! * a chaos-injected mid-upload disconnect is accounted as a failed
//!   upload (not absorbed, not a panic), the dead-socket round downlink is
//!   bridged by the handshake replay cache on rejoin, and the post-rejoin
//!   round seals bitwise-identical to the in-process oracle;
//! * 512 concurrent sessions complete one round on the fixed shard pool,
//!   and the collected aggregate is bitwise-identical to the oracle.

use fedml_he::coordinator::config::WireAuth;
use fedml_he::coordinator::{FlConfig, FlServer, Selection, Transport, TransportBackend};
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::he_agg::{native, EncryptionMask, SelectiveCodec};
use fedml_he::transport::{
    ChaosConfig, ClientSession, DownBegin, IntakeConfig, ReactorHub, SessionOpts, UpdateShape,
};
use std::sync::mpsc;
use std::time::Duration;

/// Deterministic per-(client, round) model — a plain fn so spawned client
/// threads can call it without borrows.
fn client_model(total: usize, client: u64, round: u64) -> Vec<f32> {
    (0..total)
        .map(|i| ((i as u64 + 131 * client + 7 * round) as f32 * 0.003).sin())
        .collect()
}

fn synthetic_cfg() -> FlConfig {
    FlConfig {
        model: "synthetic".into(),
        synthetic_dim: 2048,
        clients: 3,
        rounds: 3,
        local_steps: 2,
        lr: 0.2,
        ratio: 0.1,
        selection: Selection::TopP,
        dropout: 0.0,
        eval_every: 3,
        seed: 17,
        engine: fedml_he::agg_engine::Engine::Pipeline,
        shards: 2,
        ..Default::default()
    }
}

#[test]
fn hub_backend_tcp_run_bitwise_matches_sim_run() {
    // The tentpole acceptance gate of ISSUE 9: the identical phase machine
    // over the reactor backend must produce a bitwise-identical final
    // model to the in-process simulator for the same seed — only the
    // server's I/O scheduling differs.
    let sim_cfg = synthetic_cfg();
    let mut hub_cfg = synthetic_cfg();
    hub_cfg.transport = Transport::Tcp;
    hub_cfg.transport_backend = TransportBackend::Hub;
    let (ra, ga) = FlServer::standalone(sim_cfg).unwrap().run().unwrap();
    let (rb, gb) = FlServer::standalone(hub_cfg).unwrap().run().unwrap();
    assert_eq!(ga.len(), gb.len());
    for (i, (a, b)) in ga.iter().zip(gb.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "param {i}: {a} != {b}");
    }
    assert_eq!(ra.timing_source, "simulated");
    assert_eq!(rb.timing_source, "measured");
    // real frames in both directions on the reactor too
    assert!(rb.mask_downlink_bytes > 0);
    assert!(rb.rounds[1].download_bytes > 0);
    assert!(rb.fin_downlink_bytes > 0);
    assert!(rb.rounds.iter().all(|r| r.upload_bytes > 0));
    assert!(rb.rounds.iter().all(|r| r.stragglers_dropped == 0));
    for (a, b) in ra.evals.iter().zip(rb.evals.iter()) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    }
}

#[test]
fn hub_backend_authenticated_run_bitwise_matches_sim_run() {
    // --wire-auth mac on the reactor backend: the challenge/response
    // handshake and per-frame MAC trailers must stay bitwise-transparent
    // to the aggregate, exactly as on the blocking backend.
    let sim_cfg = synthetic_cfg();
    let mut hub_cfg = synthetic_cfg();
    hub_cfg.transport = Transport::Tcp;
    hub_cfg.transport_backend = TransportBackend::Hub;
    hub_cfg.wire_auth = WireAuth::Mac;
    let (_, ga) = FlServer::standalone(sim_cfg).unwrap().run().unwrap();
    let (rb, gb) = FlServer::standalone(hub_cfg).unwrap().run().unwrap();
    for (i, (a, b)) in ga.iter().zip(gb.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "param {i}: {a} != {b}");
    }
    assert_eq!(rb.timing_source, "measured");
    assert!(rb.rounds.iter().all(|r| r.upload_bytes > 0));
}

#[test]
fn chaos_disconnect_is_bridged_by_the_rejoin_replay_on_the_reactor() {
    // The session-e2e chaos gate rerun against ReactorHub: a
    // chaos-injected disconnect severs client 1 while its round-0 END
    // frame is on the wire, so the shard fails its upload (straggler
    // accounting: failed, not absorbed) AND the round-1 broadcast goes out
    // against the dead socket. The rejoining client must recover the whole
    // round-1 downlink purely from the handshake replay cache, and round 1
    // must then seal bitwise identical to the oracle.
    let ctx = fedml_he::ckks::CkksContext::new(256, 3, 30).unwrap();
    let codec = SelectiveCodec::new(ctx.clone());
    let mut rng = ChaChaRng::from_seed(9, 0);
    let (pk, _sk) = codec.ctx.keygen(&mut rng);
    let total = 700usize;
    // full mask: the uplink is HELLO, BEGIN, n_cts CT chunks, END — which
    // pins the injected disconnect onto the END frame deterministically
    let mask = EncryptionMask::full(total);
    let shape = UpdateShape::for_round(&codec.ctx, &mask);
    let end_frame = (2 + shape.n_cts + 1) as u64;
    let mut hub = ReactorHub::bind("127.0.0.1:0", ctx.params.clone(), 8).unwrap();
    let addr = hub.local_addr().unwrap().to_string();
    let opts = SessionOpts {
        connect_retry: Duration::from_secs(5),
        round_wait: Duration::from_secs(20),
        io_timeout: Duration::from_secs(5),
        ..SessionOpts::default()
    };
    let encrypt = |client: u64, round: u64| {
        let mut rng = ChaChaRng::from_seed(300 + client, round);
        codec.encrypt_update(&client_model(total, client, round), &mask, &pk, &mut rng)
    };
    let mask_bytes = mask.to_bytes();

    let (rejoin_tx, rejoin_rx) = mpsc::channel::<()>();
    let mut rejoin_rx = Some(rejoin_rx);
    let mut threads = Vec::new();
    for client in 0..2u64 {
        let addr = addr.clone();
        let params = ctx.params.clone();
        let mut opts = opts.clone();
        let codec = SelectiveCodec::new(ctx.clone());
        let pk = pk.clone();
        let mask = mask.clone();
        let rejoin_rx = if client == 1 { rejoin_rx.take() } else { None };
        if client == 1 {
            opts.chaos = Some(ChaosConfig {
                disconnect_at_frame: Some(end_frame),
                ..ChaosConfig::passthrough(0xBAD)
            });
        }
        threads.push(std::thread::spawn(move || {
            let (mut sess, _) =
                ClientSession::connect(&addr, client, params.clone(), opts.clone()).unwrap();
            sess.recv_mask(total).unwrap();
            let dl = sess.recv_round(0, Some(shape)).unwrap();
            assert!(dl.down.participate && !dl.down.has_agg);
            let mut rng = ChaChaRng::from_seed(300 + client, 0);
            let upd =
                codec.encrypt_update(&client_model(total, client, 0), &mask, &pk, &mut rng);
            let r0 = sess.upload(0, 0.5, &upd, None);
            if client == 1 {
                assert!(r0.is_err(), "the injected disconnect must fail the upload");
                // wait until the server has already broadcast round 1 into
                // the dead socket, then rejoin with a clean link
                rejoin_rx.unwrap().recv().unwrap();
                opts.chaos = None;
                let (s2, _) = ClientSession::connect(&addr, client, params, opts).unwrap();
                sess = s2;
                // the handshake replay carries the cached mask and the full
                // round-1 downlink; recv_round_any skips the mask replay
                let (round, dl) = sess.recv_round_any(Some(shape), total).unwrap();
                assert_eq!(round, 1, "replay must deliver the missed round");
                assert!(dl.down.has_agg && dl.agg.is_some());
            } else {
                r0.unwrap();
                let dl = sess.recv_round(1, Some(shape)).unwrap();
                assert!(dl.down.has_agg && dl.agg.is_some());
            }
            let mut rng = ChaChaRng::from_seed(300 + client, 1);
            let upd =
                codec.encrypt_update(&client_model(total, client, 1), &mask, &pk, &mut rng);
            sess.upload(1, 0.5, &upd, None).unwrap();
            let dl = sess.recv_round(2, Some(shape)).unwrap();
            assert!(dl.down.fin);
        }));
    }

    hub.wait_for_clients(2, Duration::from_secs(10)).unwrap();
    let out = hub.broadcast_mask(&[0, 1], &mask_bytes);
    assert!(out.failed.is_empty());
    let plan = |alpha: f64| DownBegin {
        alpha,
        alpha_mass: 0.0,
        n_cts: 0,
        n_plain: 0,
        total: 0,
        participate: true,
        has_agg: false,
        fin: false,
    };
    let out = hub.broadcast_round(0, &[(0, plan(0.5)), (1, plan(0.5))], None);
    assert!(out.failed.is_empty());
    hub.set_next_round(1);
    let outcome = hub.collect_round(
        &[(0, Some(0.5)), (1, Some(0.5))],
        shape,
        &IntakeConfig {
            round_id: 0,
            expected_uploads: 2,
            quorum: Some(1),
            straggler_timeout: Duration::from_secs(1),
            max_wait: Duration::from_secs(20),
            io_timeout: Duration::from_secs(2),
        },
    );
    // the severed upload is on the failure record, not silently absorbed
    assert_eq!(outcome.arrivals.len(), 1, "failed: {:?}", outcome.failed);
    assert_eq!(outcome.arrivals[0].client, 0);
    assert!(outcome.failed.contains(&1), "failed: {:?}", outcome.failed);

    // round 1 carries round 0's (client-0-only) aggregate; the push toward
    // client 1 hits the dead slot — the replay cache is what bridges it
    let agg0 = native::aggregate(&[encrypt(0, 0)], &[0.5], &codec.ctx.params);
    let round1 = DownBegin {
        alpha: 0.5,
        alpha_mass: 0.5,
        n_cts: agg0.cts.len(),
        n_plain: agg0.plain.len(),
        total: agg0.total,
        participate: true,
        has_agg: true,
        fin: false,
    };
    let _ = hub.broadcast_round(1, &[(0, round1), (1, round1)], Some(&agg0));
    hub.set_next_round(2);
    rejoin_tx.send(()).unwrap();
    let outcome = hub.collect_round(
        &[(0, Some(0.5)), (1, Some(0.5))],
        shape,
        &IntakeConfig {
            round_id: 1,
            expected_uploads: 2,
            quorum: None,
            straggler_timeout: Duration::from_secs(5),
            max_wait: Duration::from_secs(20),
            io_timeout: Duration::from_secs(5),
        },
    );
    assert_eq!(
        outcome.arrivals.len(),
        2,
        "round 1 after the replayed rejoin failed: {:?}",
        outcome.failed
    );
    // bitwise: the post-rejoin round matches the in-process oracle
    let oracle1 =
        native::aggregate(&[encrypt(0, 1), encrypt(1, 1)], &[0.5, 0.5], &codec.ctx.params);
    let mut arrivals = outcome.arrivals;
    arrivals.sort_by_key(|a| a.client);
    let agg1 = native::aggregate(
        &[(*arrivals[0].update).clone(), (*arrivals[1].update).clone()],
        &[0.5, 0.5],
        &codec.ctx.params,
    );
    assert_eq!(agg1.plain, oracle1.plain);
    for (a, b) in agg1.cts.iter().zip(oracle1.cts.iter()) {
        assert_eq!(a.c0, b.c0);
        assert_eq!(a.c1, b.c1);
    }
    let fin = DownBegin {
        alpha: 0.0,
        alpha_mass: 0.0,
        n_cts: 0,
        n_plain: 0,
        total: 0,
        participate: false,
        has_agg: false,
        fin: true,
    };
    let out = hub.broadcast_round(2, &[(0, fin), (1, fin)], None);
    assert!(out.failed.is_empty(), "post-rejoin fin failed: {:?}", out.failed);
    for t in threads {
        t.join().unwrap();
    }
    hub.shutdown();
}

#[test]
fn reactor_hub_carries_512_concurrent_sessions_in_one_round() {
    // The scale half of the tentpole: 512 concurrent sessions — each a
    // real ClientSession over loopback — join, receive the round downlink,
    // and upload, all carried by the fixed shard pool. The collected
    // aggregate must be bitwise-identical to the in-process oracle over
    // the same updates (hub_storm drives the same gate at 5000).
    let ctx = fedml_he::ckks::CkksContext::new(256, 3, 30).unwrap();
    let codec = SelectiveCodec::new(ctx.clone());
    let mut rng = ChaChaRng::from_seed(41, 0);
    let (pk, _sk) = codec.ctx.keygen(&mut rng);
    let total = 64usize;
    let mask = EncryptionMask::full(total);
    let shape = UpdateShape::for_round(&codec.ctx, &mask);
    const N: usize = 512;
    let alpha = 1.0 / N as f64;
    let mut hub = ReactorHub::bind("127.0.0.1:0", ctx.params.clone(), N * 2 + 8).unwrap();
    let addr = hub.local_addr().unwrap().to_string();
    let mut threads = Vec::new();
    for client in 0..N as u64 {
        let addr = addr.clone();
        let params = ctx.params.clone();
        let codec = SelectiveCodec::new(ctx.clone());
        let pk = pk.clone();
        let mask = mask.clone();
        let opts = SessionOpts {
            connect_retry: Duration::from_secs(60),
            round_wait: Duration::from_secs(120),
            io_timeout: Duration::from_secs(60),
            // small write buffer: 512 sessions must not cost 512 × 256 KiB
            write_buffer: 8 * 1024,
            ..SessionOpts::default()
        };
        threads.push(
            std::thread::Builder::new()
                .stack_size(512 * 1024)
                .spawn(move || {
                    let (mut sess, _) =
                        ClientSession::connect(&addr, client, params, opts).unwrap();
                    let dl = sess.recv_round(0, Some(shape)).unwrap();
                    assert!(dl.down.participate && !dl.down.has_agg);
                    let mut rng = ChaChaRng::from_seed(1000 + client, 0);
                    let upd = codec.encrypt_update(
                        &client_model(total, client, 0),
                        &mask,
                        &pk,
                        &mut rng,
                    );
                    sess.upload(0, alpha, &upd, None).unwrap();
                    let dl = sess.recv_round(1, Some(shape)).unwrap();
                    assert!(dl.down.fin);
                })
                .unwrap(),
        );
    }
    hub.wait_for_clients(N, Duration::from_secs(120)).unwrap();
    let plan = DownBegin {
        alpha,
        alpha_mass: 0.0,
        n_cts: 0,
        n_plain: 0,
        total: 0,
        participate: true,
        has_agg: false,
        fin: false,
    };
    let plans: Vec<(u64, DownBegin)> = (0..N as u64).map(|c| (c, plan)).collect();
    let out = hub.broadcast_round(0, &plans, None);
    assert!(out.failed.is_empty(), "round-0 downlink failed: {:?}", out.failed);
    hub.set_next_round(1);
    let expected: Vec<(u64, Option<f64>)> = (0..N as u64).map(|c| (c, Some(alpha))).collect();
    let outcome = hub.collect_round(
        &expected,
        shape,
        &IntakeConfig {
            round_id: 0,
            expected_uploads: N,
            quorum: None,
            straggler_timeout: Duration::from_secs(120),
            max_wait: Duration::from_secs(240),
            io_timeout: Duration::from_secs(120),
        },
    );
    assert_eq!(outcome.arrivals.len(), N, "failed: {:?}", outcome.failed);
    assert!(outcome.failed.is_empty(), "failed: {:?}", outcome.failed);
    let mut arrivals = outcome.arrivals;
    arrivals.sort_by_key(|a| a.client);
    let updates: Vec<_> = arrivals.iter().map(|a| (*a.update).clone()).collect();
    let alphas = vec![alpha; N];
    let agg = native::aggregate(&updates, &alphas, &codec.ctx.params);
    let oracle_updates: Vec<_> = (0..N as u64)
        .map(|c| {
            let mut rng = ChaChaRng::from_seed(1000 + c, 0);
            codec.encrypt_update(&client_model(total, c, 0), &mask, &pk, &mut rng)
        })
        .collect();
    let oracle = native::aggregate(&oracle_updates, &alphas, &codec.ctx.params);
    assert_eq!(agg.plain, oracle.plain);
    for (a, b) in agg.cts.iter().zip(oracle.cts.iter()) {
        assert_eq!(a.c0, b.c0);
        assert_eq!(a.c1, b.c1);
    }
    let fin = DownBegin {
        alpha: 0.0,
        alpha_mass: 0.0,
        n_cts: 0,
        n_plain: 0,
        total: 0,
        participate: false,
        has_agg: false,
        fin: true,
    };
    let fin_plans: Vec<(u64, DownBegin)> = (0..N as u64).map(|c| (c, fin)).collect();
    let out = hub.broadcast_round(1, &fin_plans, None);
    assert!(out.failed.is_empty(), "fin downlink failed: {:?}", out.failed);
    for t in threads {
        t.join().unwrap();
    }
    hub.shutdown();
}
