//! Observability substrate gates (DESIGN.md §10): the metrics registry
//! under concurrent recording, trace-ring overflow accounting, and the
//! golden schemas of the `--report-json` / `--trace-out` exporters.
//!
//! Metrics and the tracer are process-global, so every test serializes on
//! one mutex and resets both before making assertions.

use fedml_he::obs::{self, metrics, trace};
use fedml_he::transport::FrameKind;
use fedml_he::util::json::Json;
use std::sync::Mutex;

static GLOBAL_STATE: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    GLOBAL_STATE.lock().unwrap_or_else(|p| p.into_inner())
}

#[test]
fn concurrent_recording_matches_serial_oracle() {
    let _g = lock();
    metrics::reset();
    const THREADS: u64 = 8;
    const ITERS: u64 = 1000;
    std::thread::scope(|s| {
        for _ in 0..THREADS {
            s.spawn(|| {
                for i in 0..ITERS {
                    metrics::frame_sent(FrameKind::CtChunk as u32, 100);
                    metrics::frame_received(FrameKind::Ack as u32, 36);
                    metrics::crc_reject();
                    metrics::straggler_drops(2);
                    metrics::rejoin();
                    metrics::scratch_pool(i % 2 == 0);
                    metrics::ntt_forward();
                    metrics::ntt_inverse();
                    metrics::ntt_kernel(i % 2 == 0);
                    metrics::pack_slots(3, 4);
                    metrics::intake_enqueued();
                    metrics::session_rtt_secs(1e-6 * (i + 1) as f64);
                }
                metrics::intake_drained(ITERS);
            });
        }
    });
    let snap = metrics::snapshot();
    let total = THREADS * ITERS;
    let get = |k: &str| snap.get(k).and_then(Json::as_u64).unwrap();
    assert_eq!(
        snap.get("frames_sent").unwrap().get("ct_chunk").unwrap().as_u64(),
        Some(total)
    );
    assert_eq!(
        snap.get("bytes_sent").unwrap().get("ct_chunk").unwrap().as_u64(),
        Some(total * 100)
    );
    assert_eq!(
        snap.get("frames_received").unwrap().get("ack").unwrap().as_u64(),
        Some(total)
    );
    assert_eq!(get("crc_rejects"), total);
    assert_eq!(get("frame_rejects"), total); // crc rejects fold in
    assert_eq!(get("straggler_drops"), 2 * total);
    assert_eq!(get("rejoins"), total);
    assert_eq!(get("scratch_pool_hits"), total / 2);
    assert_eq!(get("scratch_pool_misses"), total / 2);
    assert_eq!(get("ntt_forward"), total);
    assert_eq!(get("ntt_inverse"), total);
    assert_eq!(get("ntt_kernel_avx2"), total / 2);
    assert_eq!(get("ntt_kernel_scalar"), total / 2);
    assert_eq!(get("pack_slots_used"), 3 * total);
    assert_eq!(get("pack_slots_total"), 4 * total);
    // derived gauge: 3/4 of all allocated slots carried values
    assert_eq!(
        snap.get("pack_slot_utilization").and_then(Json::as_f64),
        Some(0.75)
    );
    assert_eq!(get("intake_offered"), total);
    assert_eq!(get("intake_queue_depth"), 0);
    assert!(get("intake_queue_peak") >= ITERS); // at least one thread's burst
    assert_eq!(
        snap.get("session_rtt").unwrap().get("count").unwrap().as_u64(),
        Some(total)
    );
    metrics::reset();
    let snap = metrics::snapshot();
    assert_eq!(get_in(&snap, "crc_rejects"), 0);
    assert_eq!(
        snap.get("frames_sent").unwrap().get("ct_chunk").unwrap().as_u64(),
        Some(0)
    );
}

fn get_in(snap: &Json, k: &str) -> u64 {
    snap.get(k).and_then(Json::as_u64).unwrap()
}

#[test]
fn trace_ring_overflow_drops_oldest_and_counts() {
    let _g = lock();
    trace::clear();
    trace::set_enabled(true);
    const EXTRA: usize = 250;
    for i in 0..trace::RING_CAPACITY + EXTRA {
        let _s = obs::span_arg("test", "overflow", i as u64);
    }
    trace::set_enabled(false);
    let spans = trace::drain();
    let ours: Vec<_> = spans.iter().filter(|r| r.cat == "test").collect();
    assert_eq!(ours.len(), trace::RING_CAPACITY);
    // oldest EXTRA spans were overwritten: the survivors start at EXTRA
    assert_eq!(ours.first().unwrap().arg, EXTRA as u64);
    assert_eq!(ours.last().unwrap().arg, (trace::RING_CAPACITY + EXTRA - 1) as u64);
    let (recorded, dropped) = trace::stats();
    assert_eq!(recorded, trace::RING_CAPACITY as u64);
    assert_eq!(dropped, EXTRA as u64);
    trace::clear();
}

#[test]
fn disabled_spans_record_nothing() {
    let _g = lock();
    trace::clear();
    assert!(!trace::enabled());
    for _ in 0..64 {
        let _s = obs::span("test", "inert");
    }
    assert_eq!(trace::drain().len(), 0);
}

#[test]
fn chrome_trace_schema_holds() {
    let _g = lock();
    trace::clear();
    trace::set_enabled(true);
    {
        let _outer = obs::span("coordinator", "round");
        let _inner = obs::span_arg("codec", "encrypt_chunk", 3);
    }
    trace::set_enabled(false);
    let doc = obs::export::chrome_trace_json();
    assert_eq!(doc.get("displayTimeUnit").unwrap().as_str(), Some("ms"));
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(events.len(), 2);
    for ev in events {
        assert_eq!(ev.get("ph").unwrap().as_str(), Some("X"));
        assert!(ev.get("name").unwrap().as_str().is_some());
        assert!(ev.get("cat").unwrap().as_str().is_some());
        assert!(ev.get("ts").unwrap().as_f64().is_some());
        assert!(ev.get("dur").unwrap().as_f64().is_some());
        assert!(ev.get("pid").unwrap().as_u64().is_some());
        assert!(ev.get("tid").unwrap().as_u64().is_some());
        assert!(ev.get("args").unwrap().get("depth").is_some());
    }
    // the inner span closed first and carries its argument + depth 1
    let inner = events
        .iter()
        .find(|e| e.get("name").unwrap().as_str() == Some("encrypt_chunk"))
        .unwrap();
    assert_eq!(inner.get("args").unwrap().get("arg").unwrap().as_u64(), Some(3));
    assert_eq!(inner.get("args").unwrap().get("depth").unwrap().as_u64(), Some(1));
    // serialized form round-trips through the JSON parser
    let reparsed = Json::parse(&doc.to_string()).unwrap();
    assert_eq!(
        reparsed.get("traceEvents").unwrap().as_arr().unwrap().len(),
        2
    );
    trace::clear();
}

#[test]
fn run_report_envelope_schema_holds() {
    let _g = lock();
    metrics::reset();
    trace::clear();
    let report = Json::obj(vec![("rounds", Json::Arr(vec![])), ("clients", 3u64.into())]);
    let env = obs::run_report(report);
    assert_eq!(
        env.get("schema").unwrap().as_str(),
        Some(obs::export::REPORT_SCHEMA_NAME)
    );
    assert_eq!(
        env.get("version").unwrap().as_u64(),
        Some(obs::export::REPORT_SCHEMA_VERSION)
    );
    assert_eq!(env.get("report").unwrap().get("clients").unwrap().as_u64(), Some(3));
    let m = env.get("metrics").unwrap();
    for key in [
        "frames_sent",
        "bytes_sent",
        "frames_received",
        "bytes_received",
        "crc_rejects",
        "frame_rejects",
        "auth_rejects",
        "replay_rejects",
        "chaos_injected",
        "straggler_drops",
        "rejoins",
        "scratch_pool_hits",
        "scratch_pool_misses",
        "ntt_forward",
        "ntt_inverse",
        "ntt_kernel_avx2",
        "ntt_kernel_scalar",
        "pack_slots_used",
        "pack_slots_total",
        "pack_slot_utilization",
        "intake_offered",
        "intake_queue_depth",
        "intake_queue_peak",
        "session_rtt",
        "hub_wakeups",
        "hub_partial_reads",
        "hub_active_sessions",
        "hub_sessions_peak",
        "hub_shard_sessions",
        "hub_write_queue_depth",
        "hub_write_queue_peak",
        "ct_seed_expansions",
        "uplink_bytes_saved",
        "spans_recorded",
        "spans_dropped",
    ] {
        assert!(m.get(key).is_some(), "metrics snapshot missing key {key}");
    }
    let rtt = m.get("session_rtt").unwrap();
    for key in ["count", "sum_secs", "max_secs", "mean_secs", "log2_ns_buckets"] {
        assert!(rtt.get(key).is_some(), "rtt histogram missing key {key}");
    }
    assert!(env.get("trace").unwrap().get("spans_recorded").is_some());
    assert!(env.get("trace").unwrap().get("spans_dropped").is_some());
    // every per-kind frame counter uses the shared name table
    let sent = m.get("frames_sent").unwrap().as_obj().unwrap();
    assert_eq!(sent.len(), metrics::N_FRAME_KINDS);
    for name in metrics::FRAME_KIND_NAMES {
        assert!(sent.contains_key(name), "frames_sent missing kind {name}");
    }
}

/// `obs` deliberately has no dependency on `transport`, so the name table
/// is kept in lockstep with [`FrameKind`] by this gate: every wire id above
/// zero decodes to a kind, ids beyond the table don't, and the snapshot
/// keys match the enum variants' snake_case names.
#[test]
fn frame_kind_name_table_matches_wire_enum() {
    for id in 1..metrics::N_FRAME_KINDS as u32 {
        let kind = FrameKind::from_u32(id)
            .unwrap_or_else(|_| panic!("wire id {id} named in FRAME_KIND_NAMES but not decodable"));
        let snake: String = format!("{kind:?}")
            .chars()
            .enumerate()
            .flat_map(|(i, c)| {
                if c.is_uppercase() && i > 0 {
                    vec!['_', c.to_ascii_lowercase()]
                } else {
                    vec![c.to_ascii_lowercase()]
                }
            })
            .collect();
        assert_eq!(
            metrics::FRAME_KIND_NAMES[id as usize], snake,
            "name table out of sync at wire id {id}"
        );
    }
    assert!(
        FrameKind::from_u32(metrics::N_FRAME_KINDS as u32).is_err(),
        "FrameKind grew past the metrics name table — extend N_FRAME_KINDS"
    );
    assert_eq!(metrics::FRAME_KIND_NAMES[0], "unknown");
}
