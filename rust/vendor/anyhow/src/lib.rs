//! Offline drop-in for the subset of the `anyhow` API this workspace uses:
//! [`Error`], [`Result`], and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! The build image has no crates.io access, so this path dependency keeps
//! `cargo build` fully self-contained. The semantics match upstream for the
//! covered surface: any `std::error::Error + Send + Sync + 'static` converts
//! via `?`, and `ensure!` supports both the bare-condition and formatted
//! forms.

use std::fmt;

/// A type-erased error: a display message plus an optional source it was
/// converted from.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// The root-cause chain is flattened into the display message; expose
    /// the immediate source when one exists.
    pub fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source.as_deref().map(|e| e as _)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> anyhow::Result<()>` prints this on error exit.
        write!(f, "{}", self.msg)?;
        let mut src = self.source();
        while let Some(e) = src {
            write!(f, "\n\ncaused by: {e}")?;
            src = e.source();
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that is
// what makes this blanket conversion coherent (mirrors upstream anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error {
            msg: e.to_string(),
            source: Some(Box::new(e)),
        }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Create an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "missing");
        assert!(e.source().is_some());
    }

    #[test]
    fn macros_cover_both_forms() {
        fn checked(v: usize) -> Result<usize> {
            ensure!(v > 1);
            ensure!(v < 10, "v too large: {v}");
            if v == 5 {
                bail!("five is right out");
            }
            Ok(v)
        }
        assert_eq!(checked(3).unwrap(), 3);
        assert!(checked(0)
            .unwrap_err()
            .to_string()
            .contains("condition failed"));
        assert_eq!(checked(99).unwrap_err().to_string(), "v too large: 99");
        assert_eq!(checked(5).unwrap_err().to_string(), "five is right out");
    }

    #[test]
    fn collect_into_result() {
        let ok: Result<Vec<usize>> = (0..3).map(Ok).collect();
        assert_eq!(ok.unwrap(), vec![0, 1, 2]);
    }
}
