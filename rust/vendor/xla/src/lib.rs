//! Offline stub of the `xla` PJRT binding crate.
//!
//! The real crate links the PJRT CPU plugin and executes the AOT HLO
//! artifacts produced by `python/compile/aot.py`. This build image has no
//! crates.io access and no PJRT shared library, so this stub provides the
//! exact API surface `fedml_he::runtime` compiles against while every
//! runtime entry point returns an error. All artifact-dependent tests and
//! code paths are already gated on `artifacts/manifest.json` existing, so
//! they skip cleanly under the stub; the pure-Rust (`--backend native`) and
//! pipeline-engine paths are unaffected.
//!
//! To light up the PJRT path, replace this directory with the real binding
//! (same package name) — no source change in the main crate is needed.

use std::fmt;

/// Error type for every stubbed operation.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} requires the real PJRT binding (offline stub built; \
         artifact-gated paths are disabled)"
    )))
}

/// A host-side literal (stub: carries no data). Generic parameters are
/// deliberately unconstrained so call-site inference can never fail against
/// the stub.
pub struct Literal;

impl Literal {
    pub fn vec1<S>(_data: S) -> Literal {
        Literal
    }

    pub fn scalar(_v: f32) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-side buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client (stub: construction fails, so `Runtime::new` reports a clear
/// error instead of failing deep inside a graph call).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_errors_are_descriptive() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("PJRT"));
        let data = [1.0f32];
        let slice: &[f32] = &data;
        // double-reference call shape, as the runtime uses it
        assert!(Literal::vec1(&slice).to_vec::<f32>().is_err());
    }
}
