"""L2 model zoo in raw JAX (no flax offline) with a flat-parameter interface.

Every model exposes:
  * ``spec(name)``        — ordered list of (param_name, shape) pairs
  * ``param_count(name)`` — total flat parameter count P
  * ``init_flat(name, seed)`` — deterministic He-style init as f32[P]
  * ``forward(name, params_dict, x)`` — logits

The flat f32[P] layout is the cross-layer contract: the Rust coordinator
moves models exclusively as flat vectors (encrypting slices of them), and the
AOT graphs unflatten internally. Ordering is the ``spec`` order, row-major.

Models mirror the paper's trainable workloads:
  * ``lenet``   — LeNet-5-style CNN (Fig. 5 privacy map, Fig. 9 DLG defense)
  * ``mlp``     — "MLP (2 FC)" row of Table 4 (79,510 params exactly)
  * ``cnn``     — "CNN (2 Conv + 2 FC)" row of Table 4 (~1.66 M params)
  * ``tinybert``— miniature transformer encoder (Fig. 10 NLP inversion analog)
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Model metadata

# (C, H, W) inputs per image model; mlp takes flat 784.
INPUT_SHAPES = {
    "lenet": (1, 28, 28),
    "mlp": (784,),
    "cnn": (3, 32, 32),
}
NUM_CLASSES = 10

# tinybert config
VOCAB = 128
SEQ_LEN = 16
D_MODEL = 32
N_HEADS = 2
D_FF = 64


def spec(name: str) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered parameter spec; the flat layout contract."""
    if name == "lenet":
        return [
            ("conv1_w", (6, 1, 5, 5)),
            ("conv1_b", (6,)),
            ("conv2_w", (16, 6, 5, 5)),
            ("conv2_b", (16,)),
            ("fc1_w", (256, 120)),
            ("fc1_b", (120,)),
            ("fc2_w", (120, 84)),
            ("fc2_b", (84,)),
            ("fc3_w", (84, 10)),
            ("fc3_b", (10,)),
        ]
    if name == "mlp":
        return [
            ("fc1_w", (784, 100)),
            ("fc1_b", (100,)),
            ("fc2_w", (100, 10)),
            ("fc2_b", (10,)),
        ]
    if name == "cnn":
        return [
            ("conv1_w", (32, 3, 5, 5)),
            ("conv1_b", (32,)),
            ("conv2_w", (64, 32, 5, 5)),
            ("conv2_b", (64,)),
            # 3×32×32 → conv(5) 28 → pool 14 → conv(5) 10 → pool 5 → 64·25
            ("fc1_w", (1600, 1000)),
            ("fc1_b", (1000,)),
            ("fc2_w", (1000, 10)),
            ("fc2_b", (10,)),
        ]
    if name == "tinybert":
        return [
            ("embed", (VOCAB, D_MODEL)),
            ("pos", (SEQ_LEN, D_MODEL)),
            ("wq", (D_MODEL, D_MODEL)),
            ("wk", (D_MODEL, D_MODEL)),
            ("wv", (D_MODEL, D_MODEL)),
            ("wo", (D_MODEL, D_MODEL)),
            ("ln1_g", (D_MODEL,)),
            ("ln1_b", (D_MODEL,)),
            ("ff1_w", (D_MODEL, D_FF)),
            ("ff1_b", (D_FF,)),
            ("ff2_w", (D_FF, D_MODEL)),
            ("ff2_b", (D_MODEL,)),
            ("ln2_g", (D_MODEL,)),
            ("ln2_b", (D_MODEL,)),
            ("head_w", (D_MODEL, VOCAB)),
            ("head_b", (VOCAB,)),
        ]
    raise ValueError(f"unknown model '{name}'")


MODEL_NAMES = ("lenet", "mlp", "cnn", "tinybert")


def param_count(name: str) -> int:
    return sum(int(np.prod(s)) for _, s in spec(name))


def unflatten(name: str, flat: jax.Array) -> dict[str, jax.Array]:
    params = {}
    off = 0
    for pname, shape in spec(name):
        size = int(np.prod(shape))
        params[pname] = flat[off : off + size].reshape(shape)
        off += size
    return params


def flatten(name: str, params: dict[str, jax.Array]) -> jax.Array:
    return jnp.concatenate([params[p].reshape(-1) for p, _ in spec(name)])


def init_flat(name: str, seed: int = 0) -> np.ndarray:
    """Deterministic He-normal init (numpy; build-time only)."""
    rng = np.random.default_rng(seed)
    chunks = []
    for pname, shape in spec(name):
        if pname.endswith("_b") or pname in ("ln1_b", "ln2_b", "pos"):
            chunks.append(np.zeros(shape, np.float32).reshape(-1))
        elif pname in ("ln1_g", "ln2_g"):
            chunks.append(np.ones(shape, np.float32).reshape(-1))
        else:
            fan_in = int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]
            std = math.sqrt(2.0 / max(fan_in, 1))
            chunks.append(rng.normal(0.0, std, size=int(np.prod(shape))).astype(np.float32))
    return np.concatenate(chunks)


# ---------------------------------------------------------------------------
# Forward passes


def _conv(x, w, b):
    """NCHW valid conv."""
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y + b[None, :, None, None]


def _pool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    )


def _image_forward_lenet(p, x):
    h = jnp.tanh(_conv(x, p["conv1_w"], p["conv1_b"]))  # [B,6,24,24]
    h = _pool2(h)  # 12
    h = jnp.tanh(_conv(h, p["conv2_w"], p["conv2_b"]))  # [B,16,8,8]
    h = _pool2(h)  # 4
    h = h.reshape(h.shape[0], -1)  # 256
    h = jnp.tanh(h @ p["fc1_w"] + p["fc1_b"])
    h = jnp.tanh(h @ p["fc2_w"] + p["fc2_b"])
    return h @ p["fc3_w"] + p["fc3_b"]


def _image_forward_cnn(p, x):
    h = jax.nn.relu(_conv(x, p["conv1_w"], p["conv1_b"]))  # [B,32,28,28]
    h = _pool2(h)  # 14
    h = jax.nn.relu(_conv(h, p["conv2_w"], p["conv2_b"]))  # [B,64,10,10]
    h = _pool2(h)  # [B,64,5,5]
    h = h.reshape(h.shape[0], -1)  # 1600
    h = jax.nn.relu(h @ p["fc1_w"] + p["fc1_b"])
    return h @ p["fc2_w"] + p["fc2_b"]


def _mlp_forward(p, x):
    h = jax.nn.relu(x @ p["fc1_w"] + p["fc1_b"])
    return h @ p["fc2_w"] + p["fc2_b"]


def _tinybert_forward(p, tokens):
    """tokens: int32[B, T] → logits f32[B, T, VOCAB] (next-token style)."""
    h = p["embed"][tokens] + p["pos"][None, :, :]  # [B,T,D]

    def ln(x, g, b):
        mu = x.mean(-1, keepdims=True)
        var = ((x - mu) ** 2).mean(-1, keepdims=True)
        return (x - mu) / jnp.sqrt(var + 1e-5) * g + b

    # single-block encoder with causal attention
    q = h @ p["wq"]
    k = h @ p["wk"]
    v = h @ p["wv"]
    b, t, d = q.shape
    hd = d // N_HEADS
    q = q.reshape(b, t, N_HEADS, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, t, N_HEADS, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, t, N_HEADS, hd).transpose(0, 2, 1, 3)
    att = (q @ k.transpose(0, 1, 3, 2)) / math.sqrt(hd)  # [B,H,T,T]
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask[None, None], att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    o = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, d) @ p["wo"]
    h = ln(h + o, p["ln1_g"], p["ln1_b"])
    ff = jax.nn.relu(h @ p["ff1_w"] + p["ff1_b"]) @ p["ff2_w"] + p["ff2_b"]
    h = ln(h + ff, p["ln2_g"], p["ln2_b"])
    return h @ p["head_w"] + p["head_b"]


def forward(name: str, params: dict[str, jax.Array], x: jax.Array) -> jax.Array:
    if name == "lenet":
        return _image_forward_lenet(params, x)
    if name == "cnn":
        return _image_forward_cnn(params, x)
    if name == "mlp":
        return _mlp_forward(params, x)
    if name == "tinybert":
        return _tinybert_forward(params, x)
    raise ValueError(f"unknown model '{name}'")


def forward_flat(name: str, flat: jax.Array, x: jax.Array) -> jax.Array:
    return forward(name, unflatten(name, flat), x)
