"""Crypto parameters shared with the Rust CKKS substrate.

The Rust side (rust/src/ckks/params.rs) generates RNS moduli by a
deterministic descending scan from 2^31 for primes ≡ 1 (mod 2^14). This
module reproduces the identical scan so that the L1 Pallas kernel bakes the
exact same moduli into the AOT artifact — no cross-language data file is
needed at build time, and `aot.py` emits `artifacts/crypto_params.json`
purely as a consistency check (validated by pytest and by the Rust runtime
at artifact load).
"""

from __future__ import annotations

import dataclasses

# Must match rust/src/ckks/params.rs
WEIGHT_BITS = 20
ROOT_ORDER_LOG2 = 14  # q ≡ 1 mod 2^14
DEFAULT_N = 8192
DEFAULT_LIMBS = 4
DEFAULT_SCALING_BITS = 52

_MR_WITNESSES = (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37)


def is_prime(n: int) -> bool:
    """Deterministic Miller–Rabin for 64-bit integers (same witness set as
    the Rust implementation)."""
    if n < 2:
        return False
    for p in _MR_WITNESSES:
        if n % p == 0:
            return n == p
    d, r = n - 1, 0
    while d % 2 == 0:
        d //= 2
        r += 1
    for a in _MR_WITNESSES:
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(r - 1):
            x = x * x % n
            if x == n - 1:
                break
        else:
            return False
    return True


def generate_ntt_primes(count: int) -> list[int]:
    """First `count` primes < 2^31 with q ≡ 1 mod 2^14, scanning downward."""
    step = 1 << ROOT_ORDER_LOG2
    cand = (2**31 // step) * step + 1
    while cand >= 2**31:
        cand -= step
    primes: list[int] = []
    while len(primes) < count:
        if is_prime(cand):
            primes.append(cand)
        cand -= step
        assert cand > 2**30, "ran out of 31-bit NTT primes"
    return primes


@dataclasses.dataclass(frozen=True)
class CryptoParams:
    """The crypto context distributed to all parties."""

    n: int = DEFAULT_N
    num_limbs: int = DEFAULT_LIMBS
    scaling_bits: int = DEFAULT_SCALING_BITS

    @property
    def moduli(self) -> list[int]:
        return generate_ntt_primes(self.num_limbs)

    @property
    def batch(self) -> int:
        """Packed values per ciphertext (paper's 'HE packing batch size')."""
        return self.n // 2

    def to_dict(self) -> dict:
        return {
            "n": self.n,
            "num_limbs": self.num_limbs,
            "scaling_bits": self.scaling_bits,
            "weight_bits": WEIGHT_BITS,
            "moduli": self.moduli,
            "batch": self.batch,
        }
