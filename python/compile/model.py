"""L2 graph builders: the JAX compute graphs that get AOT-lowered to HLO.

Each builder returns (fn, example_args) pairs ready for `jax.jit(fn).lower`.
All graphs speak the flat f32[P] parameter layout of `models.py`, so the Rust
coordinator never needs to know tensor shapes.

Graphs per trainable model:
  * train_step   — one SGD step on cross-entropy: (W, x, y, lr) → (W', loss)
  * evaluate     — (W, x, y) → (loss, #correct)
  * grad         — (W, x, y) → flat gradient (attack target + FedSGD mode)
  * sensitivity  — (W, x, y) → per-parameter privacy sensitivity (§2.4):
                   S_m = (1/K) Σ_k |∂/∂y_k (∂ℓ/∂w_m)|. With ℓ = Σ_k t_k ℓ_k
                   linear in the per-sample label weights t (evaluated at
                   t = 1), the mixed derivative is the per-sample gradient,
                   so S = mean_k |grad ℓ_k| — computed with one vmapped
                   backward pass.
  * dlg_step     — gradient-inversion attack step (Zhu et al. DLG, Fig. 9):
                   gradient-matching loss descent on (dummy_x, dummy_y).

Aggregation graphs (model-independent, call the L1 Pallas kernels):
  * he_agg / he_agg_batched — modular weighted sum over ciphertext limbs
  * plain_agg               — f32 weighted sum
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import models
from .kernels import he_agg as he_agg_kernel
from .kernels import plain_agg as plain_agg_kernel

TRAIN_BATCH = 32
SENS_BATCH = 8
DLG_BATCH = 1


def _cross_entropy(logits, y):
    """Mean CE over the batch; y int32 labels."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, y[..., None], axis=-1).mean()


def _loss_flat(name, flat, x, y):
    logits = models.forward_flat(name, flat, x)
    if name == "tinybert":
        # next-token LM loss: predict y[b, t] from prefix
        return _cross_entropy(logits, y)
    return _cross_entropy(logits, y)


def _input_example(name, batch):
    if name == "tinybert":
        x = jax.ShapeDtypeStruct((batch, models.SEQ_LEN), jnp.int32)
        y = jax.ShapeDtypeStruct((batch, models.SEQ_LEN), jnp.int32)
    else:
        shape = models.INPUT_SHAPES[name]
        x = jax.ShapeDtypeStruct((batch, *shape), jnp.float32)
        y = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return x, y


def build_train_step(name):
    p = models.param_count(name)

    def train_step(flat, x, y, lr):
        loss, g = jax.value_and_grad(lambda f: _loss_flat(name, f, x, y))(flat)
        return flat - lr * g, loss

    w = jax.ShapeDtypeStruct((p,), jnp.float32)
    x, y = _input_example(name, TRAIN_BATCH)
    lr = jax.ShapeDtypeStruct((), jnp.float32)
    return train_step, (w, x, y, lr)


def build_evaluate(name):
    p = models.param_count(name)

    def evaluate(flat, x, y):
        logits = models.forward_flat(name, flat, x)
        loss = _cross_entropy(logits, y)
        correct = (logits.argmax(-1) == y).sum().astype(jnp.float32)
        return loss, correct

    w = jax.ShapeDtypeStruct((p,), jnp.float32)
    x, y = _input_example(name, TRAIN_BATCH)
    return evaluate, (w, x, y)


def build_grad(name, batch=TRAIN_BATCH):
    p = models.param_count(name)

    def grad(flat, x, y):
        return (jax.grad(lambda f: _loss_flat(name, f, x, y))(flat),)

    w = jax.ShapeDtypeStruct((p,), jnp.float32)
    x, y = _input_example(name, batch)
    return grad, (w, x, y)


def build_sensitivity(name):
    """Per-parameter privacy sensitivity over a K-sample batch."""
    p = models.param_count(name)

    def sensitivity(flat, x, y):
        def per_sample_grad(xi, yi):
            return jax.grad(
                lambda f: _loss_flat(name, f, xi[None], yi[None])
            )(flat)

        grads = jax.vmap(per_sample_grad)(x, y)  # [K, P]
        return (jnp.abs(grads).mean(axis=0),)

    w = jax.ShapeDtypeStruct((p,), jnp.float32)
    x, y = _input_example(name, SENS_BATCH)
    return sensitivity, (w, x, y)


def build_dlg_step(name):
    """One DLG attack step (image models only).

    Matching loss L = ||∇_W ℓ(x̂, softmax(ŷ)) − g*||²; descend on x̂ and ŷ.
    The observed gradient g* may be masked (selective encryption): a binary
    mask m zeroes the protected coordinates in *both* gradients, modeling an
    attacker who only sees the plaintext part.
    """
    p = models.param_count(name)
    shape = models.INPUT_SHAPES[name]

    def soft_loss(flat, x, y_soft):
        logits = models.forward_flat(name, flat, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -(y_soft * logp).sum(-1).mean()

    def dlg_step(flat, target_grad, mask, dummy_x, dummy_y_logits, lr):
        def match(dx, dy):
            y_soft = jax.nn.softmax(dy, axis=-1)
            g = jax.grad(lambda f: soft_loss(f, dx, y_soft))(flat)
            diff = (g - target_grad) * mask
            return (diff * diff).sum()

        loss, (gx, gy) = jax.value_and_grad(match, argnums=(0, 1))(
            dummy_x, dummy_y_logits
        )
        # normalized gradient descent — robust across scales
        nx = gx / (jnp.abs(gx).mean() + 1e-12)
        ny = gy / (jnp.abs(gy).mean() + 1e-12)
        return dummy_x - lr * nx, dummy_y_logits - lr * ny, loss

    w = jax.ShapeDtypeStruct((p,), jnp.float32)
    g = jax.ShapeDtypeStruct((p,), jnp.float32)
    m = jax.ShapeDtypeStruct((p,), jnp.float32)
    dx = jax.ShapeDtypeStruct((DLG_BATCH, *shape), jnp.float32)
    dy = jax.ShapeDtypeStruct((DLG_BATCH, models.NUM_CLASSES), jnp.float32)
    lr = jax.ShapeDtypeStruct((), jnp.float32)
    return dlg_step, (w, g, m, dx, dy, lr)


def build_he_agg(n_clients, num_limbs, n, moduli):
    moduli_arr = jnp.asarray(np.array(moduli, dtype=np.uint32))

    def agg(cts, weights):
        return (he_agg_kernel.he_aggregate(cts, weights, moduli_arr),)

    cts = jax.ShapeDtypeStruct((n_clients, 2, num_limbs, n), jnp.uint32)
    w = jax.ShapeDtypeStruct((n_clients, num_limbs), jnp.uint32)
    return agg, (cts, w)


def build_he_agg_batched(n_clients, chunk, num_limbs, n, moduli):
    moduli_arr = jnp.asarray(np.array(moduli, dtype=np.uint32))

    def agg(cts, weights):
        return (he_agg_kernel.he_aggregate_batched(cts, weights, moduli_arr),)

    cts = jax.ShapeDtypeStruct((n_clients, chunk, 2, num_limbs, n), jnp.uint32)
    w = jax.ShapeDtypeStruct((n_clients, num_limbs), jnp.uint32)
    return agg, (cts, w)


def build_plain_agg(n_clients, block):
    def agg(xs, weights):
        return (plain_agg_kernel.plain_aggregate(xs, weights),)

    xs = jax.ShapeDtypeStruct((n_clients, block), jnp.float32)
    w = jax.ShapeDtypeStruct((n_clients,), jnp.float32)
    return agg, (xs, w)
