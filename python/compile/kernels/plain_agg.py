"""L1 Pallas kernel: plaintext weighted aggregation.

The unencrypted half of selective aggregation —
`Σ_i α_i ((1−M) ⊙ W_i)` — is a dense f32 weighted sum. Blocked over the
parameter axis so each tile streams N client rows through VMEM once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_PARAMS = 8192


def _kernel(x_ref, w_ref, o_ref):
    """x_ref: f32[N, bp]; w_ref: f32[N]; o_ref: f32[bp]."""
    x = x_ref[...]
    w = w_ref[...]
    o_ref[...] = (x * w[:, None]).sum(axis=0)


@functools.partial(jax.jit, static_argnames=("block_p",))
def plain_aggregate(xs: jax.Array, weights: jax.Array, *, block_p: int = BLOCK_PARAMS):
    """Weighted sum of N plaintext parameter blocks.

    xs:      f32[N, B]
    weights: f32[N]
    returns  f32[B]
    """
    n_clients, b = xs.shape
    assert weights.shape == (n_clients,)
    bp = min(block_p, b)
    assert b % bp == 0
    return pl.pallas_call(
        _kernel,
        grid=(b // bp,),
        in_specs=[
            pl.BlockSpec((n_clients, bp), lambda i: (0, i)),
            pl.BlockSpec((n_clients,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(xs, weights)
