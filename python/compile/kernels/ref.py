"""Pure-jnp oracles for the L1 kernels (the build-time correctness signal).

These are deliberately written with independent, obvious numpy-style code —
no pallas, no blocking — so a kernel bug cannot be mirrored here.
"""

from __future__ import annotations

import jax.numpy as jnp


def he_aggregate_ref(cts, weights, moduli):
    """Modular weighted aggregation, direct translation of the math.

    cts: uint32[N, 2, L, n]; weights: uint32[N, L]; moduli: uint32[L]
    → uint32[2, L, n]
    """
    x = cts.astype(jnp.uint64)
    w = weights.astype(jnp.uint64)
    q = moduli.astype(jnp.uint64)
    prod = (x * w[:, None, :, None]) % q[None, None, :, None]
    acc = prod.sum(axis=0) % q[None, :, None]
    return acc.astype(jnp.uint32)


def he_aggregate_batched_ref(cts, weights, moduli):
    """cts: uint32[N, C, 2, L, n] → uint32[C, 2, L, n]."""
    x = cts.astype(jnp.uint64)
    w = weights.astype(jnp.uint64)
    q = moduli.astype(jnp.uint64)
    prod = (x * w[:, None, None, :, None]) % q[None, None, None, :, None]
    acc = prod.sum(axis=0) % q[None, None, :, None]
    return acc.astype(jnp.uint32)


def plain_aggregate_ref(xs, weights):
    """xs: f32[N, B]; weights: f32[N] → f32[B]."""
    return (xs * weights[:, None]).sum(axis=0)
