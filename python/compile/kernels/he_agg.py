"""L1 Pallas kernel: homomorphic weighted aggregation over RNS limbs.

The server hot path of Algorithm 1 — `[[W_glob]] = Σ_i α_i [[W_i]]` — reduces
to a modular multiply–accumulate over the raw ciphertext limbs:

    out[p, l, j] = Σ_i ( ct[i, p, l, j] · w[i, l] mod q_l )  mod q_l

with `p ∈ {0,1}` the ciphertext polynomial, `l` the RNS limb and `j` the
coefficient. Weights are the per-limb residues of round(α_i · 2^WEIGHT_BITS).

Hardware adaptation (DESIGN.md §6): limbs are 31-bit so every product fits
uint64 — exact integer arithmetic on the VPU, no MXU involvement. The grid
tiles (poly, limb, coeff-block); each block streams all N clients' residues
through VMEM once and accumulates in registers. Lazy reduction: products are
reduced once (`% q`, keeping terms < 2^31) and the N-term sum is folded with
a single final `% q` (valid for N < 2^33).

interpret=True is mandatory: the CPU PJRT client cannot execute Mosaic
custom-calls (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Coefficient-block width: 2048 × u64 accumulators + N client rows keeps the
# working set comfortably inside a 16 MiB VMEM budget for N ≤ 64.
BLOCK_N_COEFF = 2048


def _kernel(x_ref, w_ref, q_ref, o_ref):
    """One (poly, limb, coeff-block) tile.

    x_ref: uint32[N, 1, 1, bn]  — client ciphertext residues
    w_ref: uint32[N, 1]         — encoded weights for this limb
    q_ref: uint32[1]            — the limb modulus
    o_ref: uint32[1, 1, bn]     — aggregated residues
    """
    q = q_ref[0].astype(jnp.uint64)
    x = x_ref[...].astype(jnp.uint64)
    w = w_ref[...].astype(jnp.uint64)  # [N, 1]
    prod = (x * w[:, :, None, None]) % q  # per-term reduction: < 2^31
    acc = prod.sum(axis=0) % q  # lazy N-term accumulation
    o_ref[...] = acc.astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("block_n",))
def he_aggregate(cts: jax.Array, weights: jax.Array, moduli: jax.Array, *, block_n: int = BLOCK_N_COEFF):
    """Aggregate N clients' ciphertexts.

    cts:     uint32[N, 2, L, n]
    weights: uint32[N, L]
    moduli:  uint32[L]
    returns  uint32[2, L, n]
    """
    n_clients, polys, limbs, n = cts.shape
    assert polys == 2
    assert weights.shape == (n_clients, limbs)
    assert moduli.shape == (limbs,)
    bn = min(block_n, n)
    assert n % bn == 0
    grid = (2, limbs, n // bn)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_clients, 1, 1, bn), lambda p, l, b: (0, p, l, b)),
            pl.BlockSpec((n_clients, 1), lambda p, l, b: (0, l)),
            pl.BlockSpec((1,), lambda p, l, b: (l,)),
        ],
        out_specs=pl.BlockSpec((1, 1, bn), lambda p, l, b: (p, l, b)),
        out_shape=jax.ShapeDtypeStruct((2, limbs, n), jnp.uint32),
        interpret=True,
    )(cts, weights, moduli)


@functools.partial(jax.jit, static_argnames=("block_n",))
def he_aggregate_batched(
    cts: jax.Array, weights: jax.Array, moduli: jax.Array, *, block_n: int = BLOCK_N_COEFF
):
    """Batched variant: aggregate C ciphertexts per call (amortizes PJRT
    dispatch overhead on long models — the §Perf batching lever).

    cts:     uint32[N, C, 2, L, n]
    weights: uint32[N, L]
    moduli:  uint32[L]
    returns  uint32[C, 2, L, n]
    """
    n_clients, chunk, polys, limbs, n = cts.shape
    assert polys == 2
    bn = min(block_n, n)
    assert n % bn == 0
    grid = (chunk, 2, limbs, n // bn)
    return pl.pallas_call(
        _kernel_batched,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_clients, 1, 1, 1, bn), lambda c, p, l, b: (0, c, p, l, b)),
            pl.BlockSpec((n_clients, 1), lambda c, p, l, b: (0, l)),
            pl.BlockSpec((1,), lambda c, p, l, b: (l,)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, bn), lambda c, p, l, b: (c, p, l, b)),
        out_shape=jax.ShapeDtypeStruct((chunk, 2, limbs, n), jnp.uint32),
        interpret=True,
    )(cts, weights, moduli)


def _kernel_batched(x_ref, w_ref, q_ref, o_ref):
    q = q_ref[0].astype(jnp.uint64)
    x = x_ref[...].astype(jnp.uint64)  # [N, 1, 1, 1, bn]
    w = w_ref[...].astype(jnp.uint64)  # [N, 1]
    prod = (x * w[:, :, None, None, None]) % q
    acc = prod.sum(axis=0) % q
    o_ref[...] = acc.astype(jnp.uint32)
