"""AOT pipeline: lower every L2 graph to HLO text + write the manifest.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(behind the rust `xla` crate) rejects; the text parser reassigns ids.
See /opt/xla-example/README.md.

Usage: python -m compile.aot [--out-dir ../artifacts] [--models lenet,mlp,...]
Outputs:
  artifacts/<name>.hlo.txt      one module per graph
  artifacts/manifest.json       graph -> file, arg shapes/dtypes, metadata
  artifacts/crypto_params.json  the CKKS context (cross-checked by Rust)
  artifacts/init/<model>.f32    deterministic initial flat parameters
"""

from __future__ import annotations

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
from jax._src.lib import xla_client as xc

from . import crypto, model, models

# Fleet-wide static shapes for the aggregation artifacts.
AGG_CLIENTS = 8
AGG_CHUNK = 8
PLAIN_BLOCK = 65536


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _arg_spec(args):
    out = []
    for a in args:
        out.append({"shape": list(a.shape), "dtype": str(np.dtype(a.dtype))})
    return out


def lower_graph(name, fn, example_args, out_dir, manifest, extra=None):
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    entry = {"file": f"{name}.hlo.txt", "args": _arg_spec(example_args)}
    if extra:
        entry.update(extra)
    manifest["graphs"][name] = entry
    print(f"  {name}: {len(text)} chars")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--models", default="lenet,mlp,cnn,tinybert")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    os.makedirs(os.path.join(out_dir, "init"), exist_ok=True)

    params = crypto.CryptoParams()
    manifest = {
        "version": 1,
        "crypto": params.to_dict(),
        "agg_clients": AGG_CLIENTS,
        "agg_chunk": AGG_CHUNK,
        "plain_block": PLAIN_BLOCK,
        "train_batch": model.TRAIN_BATCH,
        "sens_batch": model.SENS_BATCH,
        "models": {},
        "graphs": {},
    }

    # Aggregation artifacts (model independent)
    print("lowering aggregation graphs")
    fn, ex = model.build_he_agg(AGG_CLIENTS, params.num_limbs, params.n, params.moduli)
    lower_graph("he_agg", fn, ex, out_dir, manifest)
    fn, ex = model.build_he_agg_batched(
        AGG_CLIENTS, AGG_CHUNK, params.num_limbs, params.n, params.moduli
    )
    lower_graph("he_agg_batched", fn, ex, out_dir, manifest)
    fn, ex = model.build_plain_agg(AGG_CLIENTS, PLAIN_BLOCK)
    lower_graph("plain_agg", fn, ex, out_dir, manifest)

    for m in args.models.split(","):
        m = m.strip()
        print(f"lowering graphs for model '{m}'")
        meta = {
            "param_count": models.param_count(m),
            "input_shape": list(models.INPUT_SHAPES.get(m, ())),
            "num_classes": models.NUM_CLASSES if m != "tinybert" else models.VOCAB,
            "seq_len": models.SEQ_LEN if m == "tinybert" else None,
            "vocab": models.VOCAB if m == "tinybert" else None,
        }
        manifest["models"][m] = meta

        fn, ex = model.build_train_step(m)
        lower_graph(f"{m}_train", fn, ex, out_dir, manifest)
        fn, ex = model.build_evaluate(m)
        lower_graph(f"{m}_eval", fn, ex, out_dir, manifest)
        fn, ex = model.build_grad(m)
        lower_graph(f"{m}_grad", fn, ex, out_dir, manifest)
        fn, ex = model.build_sensitivity(m)
        lower_graph(f"{m}_sens", fn, ex, out_dir, manifest)
        if m in ("lenet", "cnn"):
            fn, ex = model.build_dlg_step(m)
            lower_graph(f"{m}_dlg", fn, ex, out_dir, manifest)

        # deterministic initial parameters for reproducible FL runs
        init = models.init_flat(m, seed=0)
        init.tofile(os.path.join(out_dir, "init", f"{m}.f32"))

    with open(os.path.join(out_dir, "crypto_params.json"), "w") as f:
        json.dump(params.to_dict(), f, indent=1)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(manifest['graphs'])} graphs to {out_dir}")


if __name__ == "__main__":
    main()
