"""L2 model and graph-builder tests: shapes, training signal, sensitivity
properties, DLG attack step behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, models


@pytest.mark.parametrize("name", models.MODEL_NAMES)
def test_flatten_unflatten_roundtrip(name):
    flat = jnp.asarray(models.init_flat(name, seed=3))
    assert flat.shape == (models.param_count(name),)
    params = models.unflatten(name, flat)
    again = models.flatten(name, params)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(again))


def test_mlp_matches_paper_param_count():
    # Table 4 row "MLP (2 FC)": 79,510 parameters.
    assert models.param_count("mlp") == 79510


def test_cnn_param_count_near_paper():
    # Table 4 row "CNN (2 Conv + 2 FC)": 1,663,370; ours is within 0.1%.
    ours = models.param_count("cnn")
    assert abs(ours - 1663370) / 1663370 < 2e-3, ours


def _example_batch(name, batch, seed=0):
    rng = np.random.default_rng(seed)
    if name == "tinybert":
        x = rng.integers(0, models.VOCAB, size=(batch, models.SEQ_LEN)).astype(np.int32)
        y = rng.integers(0, models.VOCAB, size=(batch, models.SEQ_LEN)).astype(np.int32)
    else:
        x = rng.normal(size=(batch, *models.INPUT_SHAPES[name])).astype(np.float32)
        y = rng.integers(0, models.NUM_CLASSES, size=batch).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(y)


@pytest.mark.parametrize("name", models.MODEL_NAMES)
def test_forward_shapes(name):
    flat = jnp.asarray(models.init_flat(name))
    x, _ = _example_batch(name, 4)
    logits = models.forward_flat(name, flat, x)
    if name == "tinybert":
        assert logits.shape == (4, models.SEQ_LEN, models.VOCAB)
    else:
        assert logits.shape == (4, models.NUM_CLASSES)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("name", ["mlp", "lenet"])
def test_train_step_reduces_loss(name):
    fn, _ = model.build_train_step(name)
    fn = jax.jit(fn)
    flat = jnp.asarray(models.init_flat(name))
    x, y = _example_batch(name, model.TRAIN_BATCH)
    losses = []
    for _ in range(20):
        flat, loss = fn(flat, x, y, jnp.float32(0.1))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, losses[::5]


def test_evaluate_counts_correct():
    fn, _ = model.build_evaluate("mlp")
    fn = jax.jit(fn)
    flat = jnp.asarray(models.init_flat("mlp"))
    x, y = _example_batch("mlp", model.TRAIN_BATCH)
    loss, correct = fn(flat, x, y)
    assert 0 <= float(correct) <= model.TRAIN_BATCH
    assert float(loss) > 0


def test_grad_matches_train_step_direction():
    gfn, _ = model.build_grad("mlp")
    tfn, _ = model.build_train_step("mlp")
    flat = jnp.asarray(models.init_flat("mlp"))
    x, y = _example_batch("mlp", model.TRAIN_BATCH)
    (g,) = jax.jit(gfn)(flat, x, y)
    new_flat, _ = jax.jit(tfn)(flat, x, y, jnp.float32(0.5))
    np.testing.assert_allclose(
        np.asarray(new_flat), np.asarray(flat - 0.5 * g), rtol=1e-5, atol=1e-6
    )


@pytest.mark.parametrize("name", ["mlp", "lenet"])
def test_sensitivity_properties(name):
    fn, _ = model.build_sensitivity(name)
    fn = jax.jit(fn)
    flat = jnp.asarray(models.init_flat(name))
    x, y = _example_batch(name, model.SENS_BATCH)
    (s,) = fn(flat, x, y)
    s = np.asarray(s)
    assert s.shape == (models.param_count(name),)
    assert (s >= 0).all()
    assert s.max() > 0
    # Sensitivity is imbalanced (Fig. 5): top decile carries much more mass
    # than the bottom decile.
    srt = np.sort(s)
    top = srt[-len(s) // 10 :].sum()
    bottom = srt[: len(s) // 10].sum()
    assert top > 10 * (bottom + 1e-12)


def test_sensitivity_equals_mean_abs_per_sample_grad():
    """The mixed-derivative identity behind the implementation."""
    name = "mlp"
    fn, _ = model.build_sensitivity(name)
    flat = jnp.asarray(models.init_flat(name))
    x, y = _example_batch(name, model.SENS_BATCH)
    (s,) = jax.jit(fn)(flat, x, y)

    def loss_single(f, xi, yi):
        logits = models.forward_flat(name, f, xi[None])
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -logp[0, yi]

    grads = np.stack(
        [np.asarray(jax.grad(loss_single)(flat, x[i], y[i])) for i in range(x.shape[0])]
    )
    np.testing.assert_allclose(np.asarray(s), np.abs(grads).mean(0), rtol=1e-4, atol=1e-7)


def test_dlg_step_reduces_matching_loss():
    name = "lenet"
    fn, _ = model.build_dlg_step(name)
    fn = jax.jit(fn)
    flat = jnp.asarray(models.init_flat(name))
    # target gradient from a "victim" sample
    rng = np.random.default_rng(5)
    vx = jnp.asarray(rng.normal(size=(1, *models.INPUT_SHAPES[name])).astype(np.float32))
    vy = jnp.asarray(np.array([3], dtype=np.int32))
    gfn, _ = model.build_grad(name, batch=1)
    (target,) = jax.jit(gfn)(flat, vx, vy)
    mask = jnp.ones_like(target)
    dx = jnp.asarray(rng.normal(size=vx.shape).astype(np.float32))
    dy = jnp.zeros((1, models.NUM_CLASSES), jnp.float32)
    losses = []
    for _ in range(30):
        dx, dy, ml = fn(flat, target, mask, dx, dy, jnp.float32(0.03))
        losses.append(float(ml))
    assert losses[-1] < losses[0] * 0.7, losses[::10]


def test_dlg_mask_blocks_signal():
    """With everything masked the matching loss is identically zero — the
    attacker has no signal (full encryption)."""
    name = "lenet"
    fn, _ = model.build_dlg_step(name)
    fn = jax.jit(fn)
    flat = jnp.asarray(models.init_flat(name))
    rng = np.random.default_rng(6)
    target = jnp.asarray(rng.normal(size=models.param_count(name)).astype(np.float32))
    mask = jnp.zeros_like(target)
    dx = jnp.asarray(rng.normal(size=(1, *models.INPUT_SHAPES[name])).astype(np.float32))
    dy = jnp.zeros((1, models.NUM_CLASSES), jnp.float32)
    _, _, ml = fn(flat, target, mask, dx, dy, jnp.float32(0.1))
    assert float(ml) == 0.0
