"""L1 Pallas kernels vs pure-jnp oracles (the core correctness signal)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import crypto
from compile.kernels import he_agg, plain_agg, ref

MODULI8 = crypto.generate_ntt_primes(8)


def _random_case(rng, n_clients, limbs, n):
    moduli = np.array(MODULI8[:limbs], dtype=np.uint32)
    cts = np.empty((n_clients, 2, limbs, n), dtype=np.uint32)
    for l, q in enumerate(moduli):
        cts[:, :, l, :] = rng.integers(0, q, size=(n_clients, 2, n), dtype=np.uint64)
    w = np.empty((n_clients, limbs), dtype=np.uint32)
    for l, q in enumerate(moduli):
        w[:, l] = rng.integers(0, q, size=n_clients, dtype=np.uint64)
    return cts, w, moduli


@settings(max_examples=25, deadline=None)
@given(
    n_clients=st.integers(1, 8),
    limbs=st.integers(1, 4),
    log_n=st.integers(3, 8),
    seed=st.integers(0, 2**31),
)
def test_he_agg_matches_ref(n_clients, limbs, log_n, seed):
    rng = np.random.default_rng(seed)
    cts, w, moduli = _random_case(rng, n_clients, limbs, 1 << log_n)
    got = he_agg.he_aggregate(jnp.asarray(cts), jnp.asarray(w), jnp.asarray(moduli))
    want = ref.he_aggregate_ref(jnp.asarray(cts), jnp.asarray(w), jnp.asarray(moduli))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_he_agg_default_shape():
    """The exact artifact shape: N=8, L=4, n=8192."""
    rng = np.random.default_rng(0)
    cts, w, moduli = _random_case(rng, 8, 4, 8192)
    got = he_agg.he_aggregate(jnp.asarray(cts), jnp.asarray(w), jnp.asarray(moduli))
    want = ref.he_aggregate_ref(jnp.asarray(cts), jnp.asarray(w), jnp.asarray(moduli))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.shape == (2, 4, 8192)
    assert got.dtype == jnp.uint32


def test_he_agg_extreme_values():
    """Max residues and max weights must not overflow."""
    limbs = 4
    n = 64
    moduli = np.array(MODULI8[:limbs], dtype=np.uint32)
    cts = np.tile((moduli - 1)[None, None, :, None], (8, 2, 1, n)).astype(np.uint32)
    w = np.tile((moduli - 1)[None, :], (8, 1)).astype(np.uint32)
    got = he_agg.he_aggregate(jnp.asarray(cts), jnp.asarray(w), jnp.asarray(moduli))
    want = ref.he_aggregate_ref(jnp.asarray(cts), jnp.asarray(w), jnp.asarray(moduli))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # analytic check on one limb: ((q-1)^2 mod q) * 8 mod q = 8 mod q
    q = int(moduli[0])
    assert int(np.asarray(got)[0, 0, 0]) == (8 % q)


def test_he_agg_zero_weights_zero_output():
    rng = np.random.default_rng(1)
    cts, w, moduli = _random_case(rng, 4, 2, 128)
    w[:] = 0
    got = he_agg.he_aggregate(jnp.asarray(cts), jnp.asarray(w), jnp.asarray(moduli))
    assert int(np.asarray(got).max()) == 0


@settings(max_examples=10, deadline=None)
@given(
    n_clients=st.integers(1, 8),
    chunk=st.integers(1, 4),
    limbs=st.integers(1, 4),
    seed=st.integers(0, 2**31),
)
def test_he_agg_batched_matches_ref(n_clients, chunk, limbs, seed):
    rng = np.random.default_rng(seed)
    n = 128
    moduli = np.array(MODULI8[:limbs], dtype=np.uint32)
    cts = np.empty((n_clients, chunk, 2, limbs, n), dtype=np.uint32)
    for l, q in enumerate(moduli):
        cts[:, :, :, l, :] = rng.integers(0, q, size=(n_clients, chunk, 2, n), dtype=np.uint64)
    w = np.empty((n_clients, limbs), dtype=np.uint32)
    for l, q in enumerate(moduli):
        w[:, l] = rng.integers(0, q, size=n_clients, dtype=np.uint64)
    got = he_agg.he_aggregate_batched(jnp.asarray(cts), jnp.asarray(w), jnp.asarray(moduli))
    want = ref.he_aggregate_batched_ref(jnp.asarray(cts), jnp.asarray(w), jnp.asarray(moduli))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(
    n_clients=st.integers(1, 8),
    log_b=st.integers(4, 12),
    seed=st.integers(0, 2**31),
)
def test_plain_agg_matches_ref(n_clients, log_b, seed):
    rng = np.random.default_rng(seed)
    b = 1 << log_b
    xs = rng.normal(size=(n_clients, b)).astype(np.float32)
    w = rng.uniform(0, 1, size=n_clients).astype(np.float32)
    got = plain_agg.plain_aggregate(jnp.asarray(xs), jnp.asarray(w))
    want = ref.plain_aggregate_ref(jnp.asarray(xs), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_plain_agg_fedavg_mean():
    """Equal weights 1/N recover the mean."""
    xs = np.stack([np.full(64, 2.0), np.full(64, 4.0)]).astype(np.float32)
    w = np.array([0.5, 0.5], dtype=np.float32)
    got = plain_agg.plain_aggregate(jnp.asarray(xs), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.full(64, 3.0), rtol=1e-7)
