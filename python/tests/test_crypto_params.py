"""Cross-language crypto parameter contract.

The Rust side hardcodes the same expectations in
rust/src/ckks/params.rs::tests and rust/tests/ integration tests; if either
side changes the scan these pinned values catch the divergence.
"""

from compile import crypto

# Pinned output of the deterministic descending scan (also pinned in Rust).
KNOWN_PRIMES = [
    2147352577,
    2147205121,
    2147074049,
    2146959361,
    2146713601,
    2146418689,
    2146336769,
    2146091009,
]


def test_prime_scan_is_pinned():
    assert crypto.generate_ntt_primes(8) == KNOWN_PRIMES


def test_primes_are_ntt_friendly():
    for q in crypto.generate_ntt_primes(8):
        assert q < 2**31
        assert q > 2**30
        assert (q - 1) % (1 << crypto.ROOT_ORDER_LOG2) == 0
        assert crypto.is_prime(q)


def test_default_params():
    p = crypto.CryptoParams()
    assert p.n == 8192
    assert p.batch == 4096  # the paper's default packing batch size
    assert p.num_limbs == 4
    assert p.scaling_bits == 52
    d = p.to_dict()
    assert d["moduli"] == KNOWN_PRIMES[:4]
    assert d["weight_bits"] == 20


def test_miller_rabin_edge_cases():
    assert not crypto.is_prime(0)
    assert not crypto.is_prime(1)
    assert crypto.is_prime(2)
    assert crypto.is_prime((1 << 61) - 1)
    assert not crypto.is_prime(3215031751)  # strong pseudoprime to small bases
