"""AOT lowering sanity: graphs lower to parseable HLO text."""

import jax
import numpy as np

from compile import aot, crypto, model


def test_plain_agg_lowers_to_hlo_text():
    fn, ex = model.build_plain_agg(4, 1024)
    text = aot.to_hlo_text(jax.jit(fn).lower(*ex))
    assert text.startswith("HloModule")
    assert "f32[4,1024]" in text


def test_he_agg_lowers_to_hlo_text():
    p = crypto.CryptoParams(n=256, num_limbs=2)
    fn, ex = model.build_he_agg(4, p.num_limbs, p.n, p.moduli)
    text = aot.to_hlo_text(jax.jit(fn).lower(*ex))
    assert text.startswith("HloModule")
    assert "u32[4,2,2,256]" in text


def test_train_graph_lowers():
    fn, ex = model.build_train_step("mlp")
    text = aot.to_hlo_text(jax.jit(fn).lower(*ex))
    assert text.startswith("HloModule")
    # two outputs: params' and loss
    assert "f32[79510]" in text
