//! Deployment bandwidth study (Appendix D.5): run the same selectively-
//! encrypted FL task under the three deployment profiles and compare the
//! simulated communication share of each training cycle.
//!
//! ```bash
//! make artifacts && cargo run --release --example bandwidth_study
//! ```

use fedml_he::coordinator::{FlConfig, FlServer, Selection};
use fedml_he::netsim::{INFINIBAND, MULTI_AWS_REGION, SINGLE_AWS_REGION};
use fedml_he::runtime::Runtime;
use fedml_he::util::table::Table;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))?;
    let mut t = Table::new(
        "Bandwidth study — mlp, 4 clients, 3 rounds, full encryption",
        &["Profile", "Compute (s)", "Comm sim (s)", "Comm %", "Upload/round"],
    );
    for bw in [INFINIBAND, SINGLE_AWS_REGION, MULTI_AWS_REGION] {
        let cfg = FlConfig {
            model: "mlp".into(),
            clients: 4,
            rounds: 3,
            local_steps: 2,
            selection: Selection::Full,
            bandwidth: bw,
            eval_every: 0,
            ..Default::default()
        };
        let server = FlServer::new(&rt, cfg)?;
        let (report, _) = server.run()?;
        let compute: f64 = report
            .rounds
            .iter()
            .map(|r| r.train_secs + r.encrypt_secs + r.aggregate_secs + r.decrypt_secs)
            .sum();
        let comm: f64 = report.rounds.iter().map(|r| r.comm_secs).sum();
        t.row(vec![
            bw.name.to_string(),
            format!("{compute:.2}"),
            format!("{comm:.2}"),
            format!("{:.1}%", 100.0 * comm / (comm + compute)),
            fedml_he::util::human_bytes(report.rounds[0].upload_bytes),
        ]);
    }
    t.print();
    println!("\nLow-bandwidth (MAR) deployments are dominated by encrypted communication —");
    println!("the motivation for Selective Parameter Encryption (paper D.5 / Fig. 14b).");
    Ok(())
}
