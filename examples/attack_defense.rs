//! Attack-and-defend demo (Fig. 9 / Fig. 10 in miniature): run the DLG
//! gradient-inversion attack against a client update with and without
//! Selective Parameter Encryption, and the token-recovery attack against the
//! transformer, printing the recovery quality under each defense.
//!
//! ```bash
//! make artifacts && cargo run --release --example attack_defense
//! ```

use fedml_he::attacks::dlg::{run_dlg, DlgConfig};
use fedml_he::attacks::nlp::{recover_tokens, score_recovery};
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::fl::data::{synthetic_images, synthetic_tokens};
use fedml_he::he_agg::EncryptionMask;
use fedml_he::runtime::executor::{Arg, Runtime};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))?;

    // ---------------- DLG on LeNet ----------------
    println!("== DLG gradient inversion on LeNet ==");
    let params = rt.manifest.load_init_params("lenet")?;
    let d = synthetic_images(0, 8, (1, 28, 28), 10, 0.9, 7);
    let (x1, y1) = d.batch(0, 1);
    let b = rt.manifest.train_batch;
    let (mut xb, mut yb) = (Vec::new(), Vec::new());
    for _ in 0..b {
        xb.extend_from_slice(&x1);
        yb.extend_from_slice(&y1);
    }
    let grad = rt.execute(
        "lenet_grad",
        &[
            Arg::F32(&params, vec![params.len() as i64]),
            Arg::F32(&xb, vec![b as i64, 1, 28, 28]),
            Arg::I32(&yb, vec![b as i64]),
        ],
    )?[0]
        .to_vec::<f32>()?;
    let k = rt.manifest.sens_batch;
    let (sx, sy) = d.batch(0, k);
    let sens = rt.execute(
        "lenet_sens",
        &[
            Arg::F32(&params, vec![params.len() as i64]),
            Arg::F32(&sx, vec![k as i64, 1, 28, 28]),
            Arg::I32(&sy, vec![k as i64]),
        ],
    )?[0]
        .to_vec::<f32>()?;

    let cfg = DlgConfig::default();
    for (name, mask) in [
        ("no protection", EncryptionMask::empty(params.len())),
        ("top-10% selective", EncryptionMask::top_p(&sens, 0.1)),
    ] {
        let mut rng = ChaChaRng::from_seed(1, 0);
        let out = run_dlg(&rt, "lenet", &params, &x1, &grad, &mask, &cfg, &mut rng)?;
        println!(
            "  {name:<18}: recovered-image MSE {:.4}  PSNR {:.2} dB  SSIM {:.4}",
            out.similarity.mse, out.similarity.psnr, out.similarity.ssim
        );
    }

    // ---------------- Token recovery on tinybert ----------------
    println!("\n== Embedding-gradient token recovery on tinybert ==");
    let meta = rt.manifest.models["tinybert"].clone();
    let params = rt.manifest.load_init_params("tinybert")?;
    let data = synthetic_tokens(0, 64, meta.seq_len.unwrap(), meta.vocab.unwrap(), 3);
    // single-sentence victim batch (replicated to the fixed artifact batch)
    let (x1, y1) = data.batch(0, 1);
    let (mut x, mut y) = (Vec::new(), Vec::new());
    for _ in 0..b {
        x.extend_from_slice(&x1);
        y.extend_from_slice(&y1);
    }
    let grad = rt.execute(
        "tinybert_grad",
        &[
            Arg::F32(&params, vec![params.len() as i64]),
            Arg::I32(&x, vec![b as i64, meta.seq_len.unwrap() as i64]),
            Arg::I32(&y, vec![b as i64, meta.seq_len.unwrap() as i64]),
        ],
    )?[0]
        .to_vec::<f32>()?;
    let (sx, sy) = data.batch(0, k);
    let sens = rt.execute(
        "tinybert_sens",
        &[
            Arg::F32(&params, vec![params.len() as i64]),
            Arg::I32(&sx, vec![k as i64, meta.seq_len.unwrap() as i64]),
            Arg::I32(&sy, vec![k as i64, meta.seq_len.unwrap() as i64]),
        ],
    )?[0]
        .to_vec::<f32>()?;

    // Empirical Selection Recipe (§4.2.2): top-30% sensitive + the first
    // (embedding) and last (LM head) layers.
    let vocab = meta.vocab.unwrap();
    let d_model = 32usize;
    let embed = 0..vocab * d_model;
    let head = params.len() - (d_model * vocab + vocab)..params.len();
    for (name, mask) in [
        ("no protection".to_string(), EncryptionMask::empty(params.len())),
        ("top-30% selective".to_string(), EncryptionMask::top_p(&sens, 0.3)),
        (
            "recipe: top-30% + first/last layers".to_string(),
            EncryptionMask::recipe(&sens, 0.3, embed, head),
        ),
    ] {
        let rec = recover_tokens(&grad, &mask, vocab, d_model, 1e-4);
        let s = score_recovery(&rec, &x1);
        println!(
            "  {name:<18}: token recall {:.1}%  ({} false positives)",
            100.0 * s.recall,
            s.false_positives
        );
    }
    println!("\nSelective Parameter Encryption collapses both attacks while encrypting a");
    println!("fraction of the update — the paper's §4.2.2 result.");
    Ok(())
}
