//! Threshold-HE key management demo (Appendix B): interactive n-of-n key
//! agreement, encrypted aggregation under the joint key, distributed
//! decryption, and Shamir escrow/recovery of a dropped party's share.
//!
//! ```bash
//! cargo run --release --example threshold_demo [-- --parties 3]
//! ```

use fedml_he::ckks::{encrypt, ops, threshold, CkksContext};
use fedml_he::coordinator::key_authority;
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let n_parties: usize = args.get_parsed_or("parties", 3);
    let ctx = CkksContext::default_paper()?;
    let mut rng = ChaChaRng::from_os_entropy()?;

    println!("== threshold key agreement ({n_parties}-of-{n_parties}) ==");
    let t = std::time::Instant::now();
    let a = threshold::common_reference(&ctx.params, 7);
    let parties: Vec<threshold::ThresholdParty> = (0..n_parties)
        .map(|k| threshold::party_keygen(&ctx.params, k, &a, &mut rng))
        .collect();
    let shares: Vec<&fedml_he::ckks::RnsPoly> = parties.iter().map(|p| &p.b_share_ntt).collect();
    let pk = threshold::combine_public_key(&ctx.params, &a, &shares);
    println!("joint public key agreed in {:.3}s (2 interactive rounds)", t.elapsed().as_secs_f64());

    // Each party contributes a model chunk; server aggregates blindly.
    println!("\n== encrypted aggregation under the joint key ==");
    let values: Vec<Vec<f64>> = (0..n_parties)
        .map(|p| (0..ctx.batch()).map(|i| ((i + p * 37) as f64 * 1e-3).sin()).collect())
        .collect();
    let alphas = vec![1.0 / n_parties as f64; n_parties];
    let cts: Vec<_> = values
        .iter()
        .map(|v| {
            let pt = ctx.encoder.encode(v);
            encrypt::encrypt(&ctx.params, &pk, &pt, v.len(), &mut rng)
        })
        .collect();
    let agg = ops::weighted_sum(&cts, &alphas, &ctx.params);
    println!("aggregated {} ciphertexts ({} packed values each)", n_parties, ctx.batch());

    println!("\n== distributed decryption (all parties contribute partials) ==");
    let t = std::time::Instant::now();
    let partials: Vec<_> = parties
        .iter()
        .map(|p| threshold::partial_decrypt(&ctx.params, p, &agg, &mut rng))
        .collect();
    let m = threshold::combine_partials(&ctx.params, &agg, &partials);
    let dec = ctx.encoder.decode(&m, agg.n_values, agg.scale);
    let expected: f64 = values.iter().map(|v| v[100]).sum::<f64>() / n_parties as f64;
    println!(
        "decrypted in {:.3}s; slot[100] = {:.6} (expected {:.6}, err {:.2e})",
        t.elapsed().as_secs_f64(),
        dec[100],
        expected,
        (dec[100] - expected).abs()
    );
    anyhow::ensure!((dec[100] - expected).abs() < 1e-4);

    println!("\n== Shamir escrow: recover a dropped party's share ==");
    let bytes: Vec<u8> = parties[0].s_ntt.limb(0)
        .iter()
        .flat_map(|&c| (c as u32).to_le_bytes())
        .collect();
    let escrow = key_authority::escrow_secret(&bytes, 2, n_parties.max(3), &mut rng);
    let recovered = key_authority::recover_secret(&[&escrow[1], &escrow[2]], bytes.len());
    anyhow::ensure!(recovered == bytes);
    println!("party 0's share escrowed 2-of-{} and recovered by a quorum ✓", n_parties.max(3));
    Ok(())
}
