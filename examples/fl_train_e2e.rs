//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Trains the LeNet CNN across 8 federated clients for 300 rounds of
//! selectively-encrypted (p = 0.1) FedAvg through the complete stack:
//! ChaCha-seeded key agreement → homomorphically-aggregated sensitivity maps
//! → top-p mask → per-round local SGD (AOT train graphs via PJRT) →
//! selective CKKS encryption → XLA Pallas-kernel aggregation → key-holder
//! decryption. Logs the loss curve and accuracy, plus the full overhead
//! breakdown, and writes `e2e_report.json`.
//!
//! ```bash
//! make artifacts && cargo run --release --example fl_train_e2e [-- --rounds 300]
//! ```

use fedml_he::coordinator::{FlConfig, FlServer, Selection};
use fedml_he::runtime::Runtime;
use fedml_he::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let rounds: usize = args.get_parsed_or("rounds", 300);
    let rt = Runtime::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))?;
    let cfg = FlConfig {
        model: args.get_or("model", "lenet"),
        clients: args.get_parsed_or("clients", 8),
        rounds,
        local_steps: args.get_parsed_or("local-steps", 4),
        lr: args.get_parsed_or("lr", 0.05),
        ratio: args.get_parsed_or("ratio", 0.1),
        selection: Selection::TopP,
        samples_per_client: args.get_parsed_or("samples", 256),
        skew: 0.6,
        eval_every: args.get_parsed_or("eval-every", 20),
        seed: args.get_parsed_or("seed", 2026),
        ..Default::default()
    };
    eprintln!(
        "e2e: model={} clients={} rounds={} p={:.0}% (XLA backend, single-key)",
        cfg.model, cfg.clients, cfg.rounds, cfg.ratio * 100.0
    );
    let server = FlServer::new(&rt, cfg)?;
    let t = std::time::Instant::now();
    let (report, _global) = server.run()?;
    let wall = t.elapsed().as_secs_f64();

    println!("# E2E run — {} on {} clients, {} rounds", report.model, report.clients, rounds);
    println!(
        "mask: {:.1}% encrypted ({} of {})",
        100.0 * report.mask_ratio,
        report.encrypted_params,
        report.total_params
    );
    println!("\n## loss curve (every 10 rounds)");
    for r in report.rounds.iter().step_by(10) {
        println!("round {:>4}  loss {:.4}", r.round, r.train_loss);
    }
    println!("\n## eval curve");
    for e in &report.evals {
        println!(
            "round {:>4}  loss {:.4}  acc {:.1}%",
            e.round,
            e.loss,
            100.0 * e.accuracy
        );
    }
    let sum = |f: fn(&fedml_he::coordinator::RoundMetrics) -> f64| {
        report.rounds.iter().map(f).sum::<f64>()
    };
    println!("\n## overhead totals over {} rounds", report.rounds.len());
    println!("train     {:>9.1}s", sum(|r| r.train_secs));
    println!("encrypt   {:>9.1}s", sum(|r| r.encrypt_secs));
    println!("aggregate {:>9.1}s", sum(|r| r.aggregate_secs));
    println!("decrypt   {:>9.1}s", sum(|r| r.decrypt_secs));
    println!("comm(sim) {:>9.1}s @ {}", sum(|r| r.comm_secs), server.cfg.bandwidth.name);
    println!(
        "upload    {}",
        fedml_he::util::human_bytes(report.total_upload_bytes())
    );
    println!("wallclock {wall:.1}s");

    std::fs::write(
        concat!(env!("CARGO_MANIFEST_DIR"), "/e2e_report.json"),
        report.to_json().to_string(),
    )?;
    eprintln!("wrote e2e_report.json");

    // Validation gates: training must actually learn.
    let first = report.rounds.first().unwrap().train_loss;
    let last = report.rounds.last().unwrap().train_loss;
    anyhow::ensure!(last < first * 0.8, "loss did not fall: {first} -> {last}");
    if let Some(e) = report.evals.last() {
        anyhow::ensure!(e.accuracy > 0.3, "final accuracy too low: {}", e.accuracy);
    }
    eprintln!("e2e validation gates passed (loss {first:.3} -> {last:.3})");
    Ok(())
}
