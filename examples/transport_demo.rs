//! Transport demo: one selectively-encrypted aggregation round over real
//! loopback TCP — four concurrent clients (one disconnecting mid-upload),
//! wall-clock arrival stamps, quorum/straggler accounting, and a bitwise
//! comparison against the in-process engine. Runs without artifacts (pure
//! Rust crypto substrate); CI uses it as the bounded loopback smoke round.
//!
//! ```bash
//! cargo run --release --example transport_demo
//! ```

use fedml_he::agg_engine::{Engine, EngineConfig, StreamingAggregator};
use fedml_he::ckks::CkksContext;
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::he_agg::{native, EncryptionMask, SelectiveCodec};
use fedml_he::transport::{
    upload_encrypt_streaming, upload_partial_then_disconnect, IntakeConfig, TcpIntake,
    UpdateShape, UploadConfig,
};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let total = 20_000;
    let clients = 5; // client 4 will disconnect mid-upload
    let ctx = CkksContext::new(1024, 4, 40)?;
    let codec = SelectiveCodec::new(ctx);
    let mut rng = ChaChaRng::from_seed(7, 0);
    let (pk, sk) = codec.ctx.keygen(&mut rng);
    let sens: Vec<f32> = (0..total).map(|i| ((i * 31) % 1009) as f32).collect();
    let mask = EncryptionMask::top_p(&sens, 0.2);
    let models: Vec<Vec<f32>> = (0..clients)
        .map(|c| {
            (0..total)
                .map(|i| ((i + c * 131) as f32 * 0.0007).sin())
                .collect()
        })
        .collect();
    let alpha = 1.0 / clients as f64;

    let shape = UpdateShape::for_round(&codec.ctx, &mask);
    let intake = TcpIntake::bind("127.0.0.1:0", codec.ctx.params.clone(), shape)?;
    let addr = intake.local_addr()?.to_string();
    println!(
        "intake listening on {addr}: {} params, {:.0}% encrypted ({} ciphertext chunks + {} plain values per upload)",
        total,
        100.0 * mask.ratio(),
        shape.n_cts,
        shape.n_plain
    );

    let outcome = std::thread::scope(|s| {
        for c in 0..clients {
            let addr = addr.clone();
            let codec = &codec;
            let mask = &mask;
            let pk = &pk;
            let model = &models[c];
            s.spawn(move || {
                let cfg = UploadConfig {
                    round_id: 0,
                    client: c as u64,
                    alpha,
                    ..UploadConfig::default()
                };
                let mut rng = ChaChaRng::from_seed(1000 + c as u64, 0);
                if c == clients - 1 {
                    // failure injection: BEGIN + two chunks, then vanish
                    let upd = codec.encrypt_update(model, mask, pk, &mut rng);
                    match upload_partial_then_disconnect(&addr, &cfg, &upd, 2) {
                        Ok(bytes) => println!(
                            "client {c}: disconnected mid-upload after {bytes} bytes"
                        ),
                        Err(e) => println!("client {c}: partial upload failed early: {e}"),
                    }
                } else {
                    // ciphertext chunks stream while later chunks encrypt
                    match upload_encrypt_streaming(
                        &addr, &cfg, codec, model, mask, pk, &mut rng,
                    ) {
                        Ok(r) => println!(
                            "client {c}: uploaded {} frames / {} bytes (acked: {})",
                            r.ct_frames, r.bytes_sent, r.acked
                        ),
                        Err(e) => println!("client {c}: upload failed: {e}"),
                    }
                }
            });
        }
        intake.collect_round(&IntakeConfig {
            round_id: 0,
            expected_uploads: clients,
            quorum: Some(clients - 1),
            straggler_timeout: Duration::from_secs(2),
            max_wait: Duration::from_secs(30),
            io_timeout: Duration::from_secs(5),
        })
    })?;
    println!(
        "intake: {} arrivals, {} failed, {} bytes in {:.3}s wall-clock",
        outcome.arrivals.len(),
        outcome.failed.len(),
        outcome.bytes_received,
        outcome.elapsed_secs
    );
    for a in &outcome.arrivals {
        println!("  client {} arrived at {:.4}s", a.client, a.arrival_secs);
    }

    let engine = StreamingAggregator::new(
        &codec.ctx.params,
        EngineConfig {
            engine: Engine::Pipeline,
            shards: 4,
            quorum: Some(clients - 1),
            straggler_timeout_secs: 2.0,
        },
    );
    let mut round = engine.begin_round(Some(&mask));
    for a in outcome.arrivals {
        round.offer(a)?;
    }
    let (agg, mut stats) = round.seal()?;
    stats.offered += outcome.failed.len();
    stats.dropped_stragglers += outcome.failed.len();
    println!(
        "round sealed: {}/{} accepted, {} dropped stragglers, alpha mass {:.4}",
        stats.accepted, stats.offered, stats.dropped_stragglers, stats.alpha_mass
    );

    // Cross-check against the in-process engine over the accepted clients.
    // The engine folds the plaintext remainder in client-id order, so the
    // oracle must too — arrival order varies run to run and f64 addition is
    // not associative.
    let mut accepted_ids = stats.accepted_clients.clone();
    accepted_ids.sort_unstable();
    let mut accepted_updates = Vec::new();
    let mut accepted_alphas = Vec::new();
    for &cid in &accepted_ids {
        let mut rng = ChaChaRng::from_seed(1000 + cid, 0);
        accepted_updates.push(codec.encrypt_update(&models[cid as usize], &mask, &pk, &mut rng));
        accepted_alphas.push(alpha);
    }
    let oracle = native::aggregate(&accepted_updates, &accepted_alphas, &codec.ctx.params);
    let bitwise = agg
        .cts
        .iter()
        .zip(oracle.cts.iter())
        .all(|(a, b)| a.c0 == b.c0 && a.c1 == b.c1)
        && agg.plain == oracle.plain;
    println!("bitwise identical to the in-process engine: {bitwise}");
    anyhow::ensure!(bitwise, "TCP round diverged from the in-process engine");
    anyhow::ensure!(
        stats.dropped_stragglers >= 1,
        "the disconnecting client was not counted as a straggler"
    );

    // decrypt + renormalize to show the round is usable end to end
    let mut global = codec.decrypt_update(&agg, &mask, &sk);
    for v in global.iter_mut() {
        *v = (*v as f64 / stats.alpha_mass) as f32;
    }
    println!(
        "decrypted global model: {} params, first values {:?}",
        global.len(),
        &global[..4.min(global.len())]
    );
    Ok(())
}
