//! Adversarial transport sweep (DESIGN.md §12): run every scripted wire
//! adversary against live loopback sessions under `--wire-auth mac`
//! semantics and report pass/fail per scenario.
//!
//! Exits nonzero if any scenario fails — CI runs this as the adversarial
//! smoke gate.
//!
//! ```text
//! cargo run --release --example adversarial_transport
//! ```

fn main() {
    let reports = fedml_he::attacks::transport::run_all();
    let mut failed = 0usize;
    println!("adversarial transport sweep: {} scenarios", reports.len());
    for r in &reports {
        let verdict = if r.passed { "PASS" } else { "FAIL" };
        println!("  [{verdict}] {:<24} {}", r.name, r.detail);
        if !r.passed {
            failed += 1;
        }
    }
    println!(
        "wire counters: auth_rejects {} replay_rejects {} chaos_injected {}",
        fedml_he::obs::metrics::snapshot_auth_rejects(),
        fedml_he::obs::metrics::snapshot_replay_rejects(),
        fedml_he::obs::metrics::snapshot_chaos_injected(),
    );
    if failed > 0 {
        eprintln!("{failed} scenario(s) failed");
        std::process::exit(1);
    }
    println!("all scenarios held");
}
