//! Quickstart: a 5-round selectively-encrypted federated task on the mlp
//! artifact, 4 clients, through the full three-layer stack.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use fedml_he::coordinator::{FlConfig, FlServer, Selection};
use fedml_he::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))?;
    let cfg = FlConfig {
        model: "mlp".into(),
        clients: 4,
        rounds: 5,
        local_steps: 4,
        lr: 0.1,
        ratio: 0.1,
        selection: Selection::TopP,
        eval_every: 5,
        ..Default::default()
    };
    println!("FedML-HE quickstart: {} clients, {} rounds, top-{:.0}% selective encryption",
        cfg.clients, cfg.rounds, cfg.ratio * 100.0);
    let server = FlServer::new(&rt, cfg)?;
    let (report, _global) = server.run()?;

    println!("\nkey agreement: {:.3}s | mask agreement: {:.3}s | mask ratio: {:.1}% ({} of {} params encrypted)",
        report.keygen_secs, report.mask_agreement_secs,
        100.0 * report.mask_ratio, report.encrypted_params, report.total_params);
    for r in &report.rounds {
        println!(
            "round {:>2}: loss {:.4} | train {:.2}s enc {:.2}s agg {:.2}s dec {:.2}s | up {} down {}",
            r.round, r.train_loss, r.train_secs, r.encrypt_secs, r.aggregate_secs,
            r.decrypt_secs,
            fedml_he::util::human_bytes(r.upload_bytes),
            fedml_he::util::human_bytes(r.download_bytes),
        );
    }
    for e in &report.evals {
        println!("eval @ round {}: loss {:.4}, accuracy {:.1}%", e.round, e.loss, 100.0 * e.accuracy);
    }
    println!("\ntotal upload {} (selective) — full encryption would be {}",
        fedml_he::util::human_bytes(report.total_upload_bytes()),
        fedml_he::util::human_bytes(
            report.rounds.len() as u64 * 4 * // rounds × clients
            fedml_he::fl::model_meta::ciphertext_bytes(
                report.total_params as u64, &server.codec.ctx.params)));
    Ok(())
}
