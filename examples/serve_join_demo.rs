//! Serve/join demo: one full federated task over persistent duplex
//! loopback sessions (DESIGN.md §9) — the server pushes the agreed mask
//! and each round's partially-encrypted aggregate as real downlink frames,
//! client session threads run the exact `join` loop (train, encrypt,
//! upload, decrypt locally) — compared **bitwise** against the same-seed
//! in-process simulator. Runs without artifacts (synthetic workload); CI
//! uses it as the bounded session-transport smoke.
//!
//! ```bash
//! cargo run --release --example serve_join_demo
//! ```

use fedml_he::coordinator::{FlConfig, FlServer, Transport};

fn main() -> anyhow::Result<()> {
    let cfg = FlConfig {
        model: "synthetic".into(),
        synthetic_dim: 2048,
        clients: 3,
        rounds: 2,
        local_steps: 2,
        lr: 0.2,
        eval_every: 2,
        engine: fedml_he::agg_engine::Engine::Pipeline,
        shards: 2,
        seed: 7,
        ..Default::default()
    };

    let (sim_report, sim_global) = FlServer::standalone(cfg.clone())?.run()?;
    println!(
        "sim: {} rounds, timing={}, down {} B (simulated clock)",
        sim_report.rounds.len(),
        sim_report.timing_source,
        sim_report.rounds.iter().map(|r| r.download_bytes).sum::<u64>(),
    );

    let mut tcp_cfg = cfg;
    tcp_cfg.transport = Transport::Tcp;
    let (tcp_report, tcp_global) = FlServer::standalone(tcp_cfg)?.run()?;
    println!(
        "tcp: {} rounds, timing={}, mask downlink {} B, round downlink {} B, fin {} B (measured)",
        tcp_report.rounds.len(),
        tcp_report.timing_source,
        tcp_report.mask_downlink_bytes,
        tcp_report.rounds.iter().map(|r| r.download_bytes).sum::<u64>(),
        tcp_report.fin_downlink_bytes,
    );
    for r in &tcp_report.rounds {
        println!(
            "  round {}: {} participants, up {} B in {:.3}s, down {} B in {:.3}s",
            r.round, r.participants, r.upload_bytes, r.comm_secs, r.download_bytes,
            r.downlink_secs,
        );
    }

    anyhow::ensure!(sim_global.len() == tcp_global.len());
    for (i, (a, b)) in sim_global.iter().zip(tcp_global.iter()).enumerate() {
        anyhow::ensure!(
            a.to_bits() == b.to_bits(),
            "param {i} diverged: sim {a} vs tcp {b}"
        );
    }
    println!(
        "final models are bitwise identical across transports ({} params)",
        sim_global.len()
    );
    Ok(())
}
