//! Hub storm: the scale gate for the sharded epoll reactor backend
//! (DESIGN.md §13). Thousands of concurrent real `ClientSession`s — 5000
//! by default, first CLI arg overrides — join one coordinator round over
//! loopback, each receiving the round downlink and uploading a small
//! encrypted update, all carried by the fixed shard pool instead of one
//! thread per connection. The collected aggregate is asserted
//! **bitwise-identical** to the in-process oracle over the same updates,
//! so scheduling, partial I/O, and shard interleaving provably never
//! touch a bit of the math. CI runs it at 640 sessions as the bounded
//! smoke gate:
//!
//! ```bash
//! cargo run --release --example hub_storm          # 5000 sessions
//! cargo run --release --example hub_storm -- 640   # CI smoke scale
//! ```
//!
//! The client threads exist only to drive sockets (the reactor under test
//! is on the server side), so they are spawned with small stacks; at 5000
//! sessions the process holds ~10k file descriptors — raise `ulimit -n`
//! if the default is lower.

use fedml_he::ckks::CkksContext;
use fedml_he::crypto::prng::ChaChaRng;
use fedml_he::he_agg::{native, EncryptionMask, SelectiveCodec};
use fedml_he::transport::{
    ClientSession, DownBegin, IntakeConfig, ReactorHub, SessionOpts, UpdateShape,
};
use std::time::{Duration, Instant};

fn client_model(total: usize, client: u64) -> Vec<f32> {
    (0..total)
        .map(|i| ((i as u64 + 131 * client) as f32 * 0.003).sin())
        .collect()
}

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("session count must be a number"))
        .unwrap_or(5000);
    let total = 64usize;
    let ctx = CkksContext::new(256, 3, 30)?;
    let codec = SelectiveCodec::new(ctx.clone());
    let mut rng = ChaChaRng::from_seed(41, 0);
    let (pk, _sk) = codec.ctx.keygen(&mut rng);
    let mask = EncryptionMask::full(total);
    let shape = UpdateShape::for_round(&codec.ctx, &mask);
    let alpha = 1.0 / n as f64;

    let mut hub = ReactorHub::bind("127.0.0.1:0", ctx.params.clone(), n * 2 + 8)?;
    let addr = hub.local_addr()?.to_string();
    println!("session hub listening on {addr}; storming it with {n} concurrent sessions");

    let start = Instant::now();
    let mut threads = Vec::with_capacity(n);
    for client in 0..n as u64 {
        let addr = addr.clone();
        let params = ctx.params.clone();
        let codec = SelectiveCodec::new(ctx.clone());
        let pk = pk.clone();
        let mask = mask.clone();
        let opts = SessionOpts {
            connect_retry: Duration::from_secs(120),
            round_wait: Duration::from_secs(600),
            io_timeout: Duration::from_secs(300),
            // 5000 sessions must cost buffers, not 5000 × 256 KiB
            write_buffer: 8 * 1024,
            ..SessionOpts::default()
        };
        threads.push(
            std::thread::Builder::new()
                .stack_size(512 * 1024)
                .spawn(move || {
                    let (mut sess, _) = ClientSession::connect(&addr, client, params, opts)
                        .unwrap_or_else(|e| panic!("client {client}: connect failed: {e}"));
                    let dl = sess.recv_round(0, Some(shape)).unwrap();
                    assert!(dl.down.participate && !dl.down.has_agg);
                    let mut rng = ChaChaRng::from_seed(1000 + client, 0);
                    let upd = codec.encrypt_update(
                        &client_model(total, client),
                        &mask,
                        &pk,
                        &mut rng,
                    );
                    sess.upload(0, alpha, &upd, None)
                        .unwrap_or_else(|e| panic!("client {client}: upload failed: {e}"));
                    let dl = sess.recv_round(1, Some(shape)).unwrap();
                    assert!(dl.down.fin);
                })?,
        );
    }
    let joined = hub.wait_for_clients(n, Duration::from_secs(600))?;
    println!(
        "{} sessions joined in {:.2?} (thread-per-connection would need {} intake threads)",
        joined.len(),
        start.elapsed(),
        n
    );

    let plan = DownBegin {
        alpha,
        alpha_mass: 0.0,
        n_cts: 0,
        n_plain: 0,
        total: 0,
        participate: true,
        has_agg: false,
        fin: false,
    };
    let plans: Vec<(u64, DownBegin)> = (0..n as u64).map(|c| (c, plan)).collect();
    let t = Instant::now();
    let out = hub.broadcast_round(0, &plans, None);
    anyhow::ensure!(out.failed.is_empty(), "round downlink failed: {:?}", out.failed);
    println!(
        "round 0 broadcast to {n} sessions in {:.2?} ({} bytes)",
        t.elapsed(),
        out.bytes_sent
    );

    hub.set_next_round(1);
    let expected: Vec<(u64, Option<f64>)> = (0..n as u64).map(|c| (c, Some(alpha))).collect();
    let t = Instant::now();
    let outcome = hub.collect_round(
        &expected,
        shape,
        &IntakeConfig {
            round_id: 0,
            expected_uploads: n,
            quorum: None,
            straggler_timeout: Duration::from_secs(600),
            max_wait: Duration::from_secs(900),
            io_timeout: Duration::from_secs(600),
        },
    );
    anyhow::ensure!(
        outcome.arrivals.len() == n,
        "only {}/{n} uploads arrived (failed: {:?})",
        outcome.arrivals.len(),
        outcome.failed
    );
    println!("collected {n} uploads in {:.2?}", t.elapsed());

    // bitwise gate: the storm's aggregate vs the in-process oracle
    let mut arrivals = outcome.arrivals;
    arrivals.sort_by_key(|a| a.client);
    let updates: Vec<_> = arrivals.iter().map(|a| (*a.update).clone()).collect();
    let alphas = vec![alpha; n];
    let agg = native::aggregate(&updates, &alphas, &codec.ctx.params);
    let oracle_updates: Vec<_> = (0..n as u64)
        .map(|c| {
            let mut rng = ChaChaRng::from_seed(1000 + c, 0);
            codec.encrypt_update(&client_model(total, c), &mask, &pk, &mut rng)
        })
        .collect();
    let oracle = native::aggregate(&oracle_updates, &alphas, &codec.ctx.params);
    anyhow::ensure!(agg.plain == oracle.plain, "plain segment diverged from the oracle");
    for (i, (a, b)) in agg.cts.iter().zip(oracle.cts.iter()).enumerate() {
        anyhow::ensure!(
            a.c0 == b.c0 && a.c1 == b.c1,
            "ciphertext {i} diverged from the oracle"
        );
    }
    println!("aggregate over the wire is bitwise-identical to the in-process oracle");

    let fin = DownBegin {
        alpha: 0.0,
        alpha_mass: 0.0,
        n_cts: 0,
        n_plain: 0,
        total: 0,
        participate: false,
        has_agg: false,
        fin: true,
    };
    let fin_plans: Vec<(u64, DownBegin)> = (0..n as u64).map(|c| (c, fin)).collect();
    let out = hub.broadcast_round(1, &fin_plans, None);
    anyhow::ensure!(out.failed.is_empty(), "fin downlink failed: {:?}", out.failed);
    for t in threads {
        t.join().expect("client thread panicked");
    }
    hub.shutdown();

    let snap = fedml_he::obs::metrics::snapshot();
    for key in ["hub_sessions_peak", "hub_wakeups", "hub_partial_reads", "hub_write_queue_peak"] {
        if let Some(v) = snap.get(key) {
            println!("  {key}: {v}");
        }
    }
    println!("PASS: {n} concurrent reactor sessions, one round, bitwise-identical aggregate");
    Ok(())
}
